"""Serving-stack benchmark: engine smoke + cluster serving traces.

Two layers:

  * **engine** — a real (reduced-config) ``AsyncServeEngine`` run on this
    host: paged KV cache, continuous batching (fused prefill+decode
    iterations), prefix-hash reuse, greedy decode.  ``burst`` submits
    every request up front; ``paced`` trickles them in while the engine
    steps; ``burst_unfused`` replays the burst with fused batching off —
    the continuous-batching comparison row.  Engines are ``warmup()``-ed
    first so TTFT/TPOT percentiles measure steady state; jit compile
    time is reported separately (``compile_s``).  Latencies are
    wall-clock (vary by machine); cache-hit rate and token counts exact.
  * **cluster** — the deterministic serving-trace mode of the cluster
    simulator.  ``poisson``/``burst`` admit a 2-replica service alongside
    the default training mix (unchanged legacy scenarios), and the
    ``overload_*`` sweep drives one replica past saturation at 1x/2x
    arrival rates with ``ServiceConfig.autoscale`` off vs on — the
    SLO-driven replica-autoscaling comparison (scale-ups lease chips
    through the ordinary scheduler path).

``report()`` returns the JSON artifact ``run.py --bench serve_bench``
writes to ``results/serve_bench.json``; schema asserted by
``tests/test_artifacts.py``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.cluster.simulator import (ClusterSimulator, ServiceConfig,
                                     TraceConfig)
from repro.configs import get_config, reduced
from repro.configs.base import PolicyConfig
from repro.models import lm
from repro.serve import SLO, AsyncServeEngine, ServeRequest

ARCH = "qwen2-0.5b"
N_REQUESTS = 10
PROMPT_LEN = 40
PREFIX_LEN = 24
MAX_NEW = 8
N_SLOTS = 10            # the whole burst admits at once
# per-request targets: achievable in steady state (warmed, fused) on a
# CPU host, missed when prefill is throttled behind the decode batch
REQUEST_SLO = SLO(ttft_s=2.5, tpot_s=0.25)


# Perf-trajectory spec for results/BENCH_serve_bench.json (see
# docs/tracking.md).  Gated metrics come from the deterministic cluster
# layer and the engine's exact accounting; the engine's SLO attainment
# and throughput are gated too (warmup makes them steady-state), with a
# generous band on throughput because it is wall-clock; per-token
# latency percentiles stay info-only.
TRAJECTORY = {
    "cluster_poisson_ttft_p99_s": {"direction": "down"},
    "cluster_poisson_tpot_p50_s": {"direction": "down"},
    "cluster_poisson_slo_attainment": {"direction": "up"},
    "cluster_poisson_throughput_tok_s": {"direction": "up"},
    "cluster_autoscale_slo_attainment": {"direction": "up"},
    "cluster_autoscale_ttft_p99_s": {"direction": "down"},
    "engine_paced_cache_hit_rate": {"direction": "up"},
    "engine_burst_slo_attainment": {"direction": "up"},
    "engine_burst_throughput_tok_s": {"direction": "up", "band": 0.5},
    "engine_burst_ttft_p50_s": {"direction": "info"},
}


def trajectory_row(rep: Dict[str, object]) -> Dict[str, float]:
    """Flatten one report() into the gated summary-row metrics."""
    svc = rep["cluster"]["poisson"]["serving"]["chat"]
    auto = rep["cluster"]["overload_autoscale_2x"]["serving"]["chat"]
    eng = rep["engine"]["burst"]
    paced = rep["engine"]["paced"]
    return {
        "cluster_poisson_ttft_p99_s": svc["ttft_s"]["p99"],
        "cluster_poisson_tpot_p50_s": svc["tpot_s"]["p50"],
        "cluster_poisson_slo_attainment": svc["slo_attainment"],
        "cluster_poisson_throughput_tok_s": svc["throughput_tok_s"],
        "cluster_autoscale_slo_attainment": auto["slo_attainment"],
        "cluster_autoscale_ttft_p99_s": auto["ttft_s"]["p99"],
        "engine_paced_cache_hit_rate": paced["kv_pages"]["hit_rate"],
        "engine_burst_slo_attainment": eng["slo_attainment"],
        "engine_burst_throughput_tok_s": eng["throughput_tok_s"],
        "engine_burst_ttft_p50_s": eng["ttft_s"]["p50"],
    }


def _requests(vocab: int) -> List[ServeRequest]:
    """Shared-prefix request mix: two system prompts, per-request tails."""
    rng = np.random.RandomState(0)
    prefixes = [list(rng.randint(0, vocab, PREFIX_LEN)) for _ in range(2)]
    out = []
    for i in range(N_REQUESTS):
        tail = list(np.random.RandomState(100 + i).randint(
            0, vocab, PROMPT_LEN - PREFIX_LEN))
        out.append(ServeRequest(i, prefixes[i % 2] + tail, max_new=MAX_NEW,
                                slo=REQUEST_SLO))
    return out


def _engine(params, cfg, *, fused: bool = True) -> AsyncServeEngine:
    policy = PolicyConfig(compute_dtype="float32", remat="none",
                          attn_impl="full")
    eng = AsyncServeEngine(cfg, params, policy, n_slots=N_SLOTS, max_seq=96,
                           page_size=8, prefill_chunk=16, prefill_batch=2,
                           token_budget=N_SLOTS * 16 + N_SLOTS, fused=fused)
    eng.warmup()        # steady-state latencies; compile_s reported apart
    return eng


def engine_scenarios() -> Dict[str, Dict[str, object]]:
    cfg = reduced(get_config(ARCH))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    out: Dict[str, Dict[str, object]] = {}

    eng = _engine(params, cfg)
    for r in _requests(cfg.vocab_size):
        eng.submit(r)
    eng.run()
    out["burst"] = eng.report()

    eng = _engine(params, cfg)
    pending = _requests(cfg.vocab_size)
    while pending or not eng.sched.all_done():
        if pending:                      # one new arrival per iteration
            eng.submit(pending.pop(0))
        if eng.step() == 0 and not pending and not eng.sched.active:
            break
        eng.stats.mark(eng.now())
    out["paced"] = eng.report()

    # continuous-batching comparison row: same burst, fused=False runs
    # the legacy alternating prefill-batch / decode-batch iterations
    eng = _engine(params, cfg, fused=False)
    for r in _requests(cfg.vocab_size):
        eng.submit(r)
    eng.run()
    out["burst_unfused"] = eng.report()
    return out


def _cluster_cfg(arrival: str) -> TraceConfig:
    return TraceConfig(
        n_jobs=12, arrival_rate_hz=0.2, seed=7,
        failures=((300.0, 8),), repair_after_s=180.0,
        services=(ServiceConfig(
            name="chat", arch="llama3.2-3b", shape_name="decode_32k",
            n_replicas=2, chips_per_replica=64, n_requests=160,
            arrival_rate_hz=2.0, arrival=arrival, prompt_len=2048,
            max_new=128, n_prefixes=6, prefix_len=1024,
            prefill_chunk=512),))


# single replica, request rate past its saturation point at 2x: the
# fixed service queues without bound while autoscale leases replicas
OVERLOAD_RATE_HZ = 20.0
OVERLOAD_N_REQUESTS = 320


def _overload_cfg(load: float, autoscale: bool) -> TraceConfig:
    extra = dict(autoscale=True, autoscale_interval_s=0.5,
                 max_replicas=8, scale_up_queue=1.0,
                 scale_down_queue=0.25) if autoscale else {}
    return TraceConfig(
        n_jobs=0, failures=(), seed=3,
        services=(ServiceConfig(
            name="chat", arch="llama3.2-3b", shape_name="decode_32k",
            n_replicas=1, chips_per_replica=64,
            n_requests=OVERLOAD_N_REQUESTS,
            arrival_rate_hz=OVERLOAD_RATE_HZ * load, arrival="poisson",
            prompt_len=2048, max_new=256, n_prefixes=6, prefix_len=1024,
            prefill_chunk=512, ttft_slo_s=2.0, tpot_slo_s=0.5,
            **extra),))


def cluster_scenarios() -> Dict[str, Dict[str, object]]:
    out: Dict[str, Dict[str, object]] = {}
    for arrival in ("poisson", "burst"):
        rep = ClusterSimulator(_cluster_cfg(arrival)).run()
        out[arrival] = {
            "jobs": rep["jobs"],
            "serving": rep["serving"],
            "link_traffic_gb": rep["link_traffic_gb"],
            "pool_utilization": rep["pool_utilization"],
            "makespan_s": rep["makespan_s"],
        }
    # SLO-driven autoscaling sweep: fixed vs autoscale at 1x and 2x load
    for name, load, autoscale in (
            ("overload_fixed_1x", 1.0, False),
            ("overload_fixed_2x", 2.0, False),
            ("overload_autoscale_1x", 1.0, True),
            ("overload_autoscale_2x", 2.0, True)):
        rep = ClusterSimulator(_overload_cfg(load, autoscale)).run()
        out[name] = {
            "serving": rep["serving"],
            "makespan_s": rep["makespan_s"],
        }
    return out


def report() -> Dict[str, object]:
    return {
        "bench": "serve_bench",
        "config": {"arch": ARCH, "n_requests": N_REQUESTS,
                   "prompt_len": PROMPT_LEN, "prefix_len": PREFIX_LEN,
                   "max_new": MAX_NEW, "n_slots": N_SLOTS,
                   "ttft_slo_s": REQUEST_SLO.ttft_s,
                   "tpot_slo_s": REQUEST_SLO.tpot_s,
                   "overload_rate_hz": OVERLOAD_RATE_HZ,
                   "overload_n_requests": OVERLOAD_N_REQUESTS},
        "engine": engine_scenarios(),
        "cluster": cluster_scenarios(),
    }


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    rep = report()
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    for name, sc in rep["engine"].items():
        rows.append((
            f"serve_bench/engine_{name}", us,
            f"reqs={sc['requests']['completed']}/"
            f"{sc['requests']['submitted']} "
            f"ttft_p50={sc['ttft_s']['p50']*1e3:.0f}ms "
            f"tpot_p50={sc['tpot_s']['p50']*1e3:.0f}ms "
            f"tput={sc['throughput_tok_s']:.1f}tok/s "
            f"slo={sc['slo_attainment']*100:.0f}% "
            f"compile={sc['compile_s']:.1f}s "
            f"hit={sc['kv_pages']['hit_rate']*100:.0f}%"))
    for name, sc in rep["cluster"].items():
        svc = sc["serving"]["chat"]
        scale = svc.get("autoscale", {})
        extra = (f" peak_reps={scale['peak_replicas']}"
                 f" +{scale['scale_ups']}/-{scale['scale_downs']}"
                 if scale else "")
        rows.append((
            f"serve_bench/cluster_{name}", us,
            f"reqs={svc['requests']['completed']} "
            f"ttft_p99={svc['ttft_s']['p99']:.2f}s "
            f"tpot_p50={svc['tpot_s']['p50']*1e3:.0f}ms "
            f"slo={svc['slo_attainment']*100:.0f}%" + extra))
    return rows
