"""Serving-stack benchmark: engine smoke + cluster serving trace.

Two layers, two request-arrival scenarios each:

  * **engine** — a real (reduced-config) ``AsyncServeEngine`` run on this
    host: paged KV cache, chunked prefill, prefix-hash reuse, greedy
    decode.  ``burst`` submits every request up front; ``paced`` trickles
    them in while the engine steps.  TTFT/TPOT/throughput are wall-clock
    (so they vary by machine); cache-hit rate and token counts are exact.
  * **cluster** — the deterministic serving-trace mode of the cluster
    simulator: a 2-replica ``ServeJob`` service admitted *alongside* the
    default training-job mix, ``poisson`` vs ``burst`` request arrivals,
    per-replica prefix caches and per-link KV-traffic accounting.

``report()`` returns the JSON artifact ``run.py --bench serve_bench``
writes to ``results/serve_bench.json``; schema asserted by
``tests/test_artifacts.py``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.cluster.simulator import (ClusterSimulator, ServiceConfig,
                                     TraceConfig)
from repro.configs import get_config, reduced
from repro.configs.base import PolicyConfig
from repro.models import lm
from repro.serve import AsyncServeEngine, ServeRequest

ARCH = "qwen2-0.5b"
N_REQUESTS = 10
PROMPT_LEN = 40
PREFIX_LEN = 24
MAX_NEW = 8


# Perf-trajectory spec for results/BENCH_serve_bench.json (see
# docs/tracking.md).  Gated metrics come from the deterministic cluster
# layer (poisson arrivals) and the engine's exact cache-hit accounting;
# the engine's wall-clock latencies vary by host and stay info-only.
TRAJECTORY = {
    "cluster_poisson_ttft_p99_s": {"direction": "down"},
    "cluster_poisson_tpot_p50_s": {"direction": "down"},
    "cluster_poisson_slo_attainment": {"direction": "up"},
    "cluster_poisson_throughput_tok_s": {"direction": "up"},
    "engine_burst_cache_hit_rate": {"direction": "up"},
    "engine_burst_throughput_tok_s": {"direction": "info"},
    "engine_burst_ttft_p50_s": {"direction": "info"},
}


def trajectory_row(rep: Dict[str, object]) -> Dict[str, float]:
    """Flatten one report() into the gated summary-row metrics."""
    svc = rep["cluster"]["poisson"]["serving"]["chat"]
    eng = rep["engine"]["burst"]
    return {
        "cluster_poisson_ttft_p99_s": svc["ttft_s"]["p99"],
        "cluster_poisson_tpot_p50_s": svc["tpot_s"]["p50"],
        "cluster_poisson_slo_attainment": svc["slo_attainment"],
        "cluster_poisson_throughput_tok_s": svc["throughput_tok_s"],
        "engine_burst_cache_hit_rate": eng["kv_pages"]["hit_rate"],
        "engine_burst_throughput_tok_s": eng["throughput_tok_s"],
        "engine_burst_ttft_p50_s": eng["ttft_s"]["p50"],
    }


def _requests(vocab: int) -> List[ServeRequest]:
    """Shared-prefix request mix: two system prompts, per-request tails."""
    rng = np.random.RandomState(0)
    prefixes = [list(rng.randint(0, vocab, PREFIX_LEN)) for _ in range(2)]
    out = []
    for i in range(N_REQUESTS):
        tail = list(np.random.RandomState(100 + i).randint(
            0, vocab, PROMPT_LEN - PREFIX_LEN))
        out.append(ServeRequest(i, prefixes[i % 2] + tail, max_new=MAX_NEW))
    return out


def _engine(params, cfg) -> AsyncServeEngine:
    policy = PolicyConfig(compute_dtype="float32", remat="none",
                          attn_impl="full")
    return AsyncServeEngine(cfg, params, policy, n_slots=4, max_seq=96,
                            page_size=8, prefill_chunk=16, prefill_batch=2)


def engine_scenarios() -> Dict[str, Dict[str, object]]:
    cfg = reduced(get_config(ARCH))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    out: Dict[str, Dict[str, object]] = {}

    eng = _engine(params, cfg)
    for r in _requests(cfg.vocab_size):
        eng.submit(r)
    eng.run()
    out["burst"] = eng.report()

    eng = _engine(params, cfg)
    pending = _requests(cfg.vocab_size)
    while pending or not eng.sched.all_done():
        if pending:                      # one new arrival per iteration
            eng.submit(pending.pop(0))
        if eng.step() == 0 and not pending and not eng.sched.active:
            break
        eng.stats.mark(eng.now())
    out["paced"] = eng.report()
    return out


def _cluster_cfg(arrival: str) -> TraceConfig:
    return TraceConfig(
        n_jobs=12, arrival_rate_hz=0.2, seed=7,
        failures=((300.0, 8),), repair_after_s=180.0,
        services=(ServiceConfig(
            name="chat", arch="llama3.2-3b", shape_name="decode_32k",
            n_replicas=2, chips_per_replica=64, n_requests=160,
            arrival_rate_hz=2.0, arrival=arrival, prompt_len=2048,
            max_new=128, n_prefixes=6, prefix_len=1024,
            prefill_chunk=512),))


def cluster_scenarios() -> Dict[str, Dict[str, object]]:
    out: Dict[str, Dict[str, object]] = {}
    for arrival in ("poisson", "burst"):
        rep = ClusterSimulator(_cluster_cfg(arrival)).run()
        out[arrival] = {
            "jobs": rep["jobs"],
            "serving": rep["serving"],
            "link_traffic_gb": rep["link_traffic_gb"],
            "pool_utilization": rep["pool_utilization"],
            "makespan_s": rep["makespan_s"],
        }
    return out


def report() -> Dict[str, object]:
    return {
        "bench": "serve_bench",
        "config": {"arch": ARCH, "n_requests": N_REQUESTS,
                   "prompt_len": PROMPT_LEN, "prefix_len": PREFIX_LEN,
                   "max_new": MAX_NEW},
        "engine": engine_scenarios(),
        "cluster": cluster_scenarios(),
    }


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    rep = report()
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    for name, sc in rep["engine"].items():
        rows.append((
            f"serve_bench/engine_{name}", us,
            f"reqs={sc['requests']['completed']}/"
            f"{sc['requests']['submitted']} "
            f"ttft_p50={sc['ttft_s']['p50']*1e3:.0f}ms "
            f"tpot_p50={sc['tpot_s']['p50']*1e3:.0f}ms "
            f"tput={sc['throughput_tok_s']:.1f}tok/s "
            f"hit={sc['kv_pages']['hit_rate']*100:.0f}%"))
    for name, sc in rep["cluster"].items():
        svc = sc["serving"]["chat"]
        hits = " ".join(
            f"{r.split('/')[-1]}={v['cache_hit_rate']*100:.0f}%"
            for r, v in svc["replicas"].items())
        rows.append((
            f"serve_bench/cluster_{name}", us,
            f"reqs={svc['requests']['completed']} "
            f"ttft_p99={svc['ttft_s']['p99']:.2f}s "
            f"tpot_p50={svc['tpot_s']['p50']*1e3:.0f}ms "
            f"slo={svc['slo_attainment']*100:.0f}% hit[{hits}]"))
    return rows
