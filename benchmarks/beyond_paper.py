"""Beyond-paper distributed-optimization rungs on the production mesh.

The paper stops at ZeRO + mixed precision on 8 GPUs.  At 512 chips across
two pods the slow fabric is the DCN pod axis, and two further rungs apply
(both implemented in the framework, priced here with the same collective
math the cost model uses):

  1. hierarchical allreduce — reduce-scatter intra-pod, all-reduce the
     1/256 shard across pods, all-gather intra-pod.
  2. int8 error-feedback compression on the cross-pod hop only.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.configs import get_config
from repro.core.hierarchy import flat_time, hierarchical_time
from repro.core.compose import production_system


def run() -> List[Tuple[str, float, str]]:
    rows = []
    sys_ = production_system(multi_pod=True)
    fast_n = 256
    slow_n = 2
    fast_bw = sys_.axis_bandwidth("data")
    slow_bw = sys_.axis_bandwidth("pod")
    for arch in ("llama3.2-3b", "command-r-35b", "llama4-scout-17b-a16e"):
        t0 = time.perf_counter()
        cfg = get_config(arch)
        gbytes = cfg.param_count() * 2.0          # bf16 grads
        t_flat = flat_time(gbytes, fast_n * slow_n, slow_bw)
        t_hier = hierarchical_time(gbytes, fast_n, slow_n, fast_bw, slow_bw)
        t_hier_int8 = hierarchical_time(gbytes, fast_n, slow_n, fast_bw,
                                        slow_bw, compress=0.25)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"beyond/{arch}", us,
                     f"flat={t_flat*1e3:.1f}ms "
                     f"hier={t_hier*1e3:.1f}ms "
                     f"hier+int8={t_hier_int8*1e3:.1f}ms "
                     f"speedup={t_flat/t_hier_int8:.1f}x"))
    return rows
