"""Fig 16: software-optimization ladder on BERT-large fine-tuning.

Paper claims checked:
  * mixed precision: >50% speedup, >70% on falcon-attached GPUs
  * DDP vs one-node DP: >80% speedup on local GPUs
  * sharded (ZeRO): per-GPU batch 6 -> 10 fits, further per-sample win

Mode model (constants in benchmarks/paper_model.py):
  * DP   — single-process DataParallel: replicate params to 7 peers +
           gather through one master link, no overlap.
  * DDP  — ring allreduce (fp32 master grads), bucketed overlap 0.4.
  * fp16 — compute at the fp16 throughput (fp32 at ~30% of it).
  * sharded — ZeRO memory win raises per-GPU batch 6 -> 10.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from benchmarks.paper_model import (EFF_BW, N_GPUS, OVERLAP, STEP_OVERHEAD,
                                    THROUGHPUT, allreduce_wire_bytes)
from repro.configs.paper_bench import PAPER_WORKLOADS

BERT_L = next(w for w in PAPER_WORKLOADS if w.name == "bert-large")
TP_FP16 = THROUGHPUT["bert-large"]          # 30 samples/s/GPU
TP_FP32 = TP_FP16 * 0.3                     # fp32 ~ 9 samples/s/GPU
P_BYTES = BERT_L.params_paper * 4           # fp32 params/grads
# single-process DataParallel serializes 8 replicas' launches through one
# Python process (GIL) — the documented reason DP underutilizes GPUs
DP_GIL_EFFICIENCY = 0.5


def _step(mode: str, fabric: str) -> Tuple[float, int]:
    """Returns (seconds per SAMPLE, per-GPU batch)."""
    bw = EFF_BW[fabric]
    fp16 = "fp16" in mode
    batch = 10 if "sharded" in mode else 6
    comp = batch / (TP_FP16 if fp16 else TP_FP32)
    if mode.startswith("DP"):
        # master replicates params + gathers grads: 7 transfers each way
        comm = 2.0 * (N_GPUS - 1) * P_BYTES / (bw * N_GPUS / 2)
        comp = comp / DP_GIL_EFFICIENCY
        step = STEP_OVERHEAD + comp + comm          # no overlap
    else:
        comm = allreduce_wire_bytes(BERT_L.params_paper)
        step = STEP_OVERHEAD + comp + max(0.0, comm / bw - OVERLAP * comp)
    return step / batch, batch


MODES = ("DP+fp32", "DP+fp16", "DDP+fp32", "DDP+fp16", "DDP+fp16+sharded")


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for fabric in ("localGPUs", "falconGPUs"):
        t0 = time.perf_counter()
        per: Dict[str, Tuple[float, int]] = {m: _step(m, fabric)
                                             for m in MODES}
        us = (time.perf_counter() - t0) * 1e6
        base = per["DP+fp32"][0]
        mixed = (per["DDP+fp32"][0] / per["DDP+fp16"][0] - 1) * 100
        ddp = (per["DP+fp16"][0] / per["DDP+fp16"][0] - 1) * 100
        shard = (per["DDP+fp16"][0] / per["DDP+fp16+sharded"][0] - 1) * 100
        checks = [f"mixed=+{mixed:.0f}%"]
        if fabric == "localGPUs":
            checks += ["mixed>50%:" + ("OK" if mixed > 50 else "FAIL"),
                       f"DDPvsDP=+{ddp:.0f}%",
                       "DDP>80%:" + ("OK" if ddp > 80 else "FAIL")]
        else:
            checks += ["mixed>70%:" + ("OK" if mixed > 70 else "FAIL")]
        checks.append(f"sharded=+{shard:.0f}%/sample(batch 6->10)")
        for m in MODES:
            t, b = per[m]
            rows.append((f"fig16/{fabric}/{m}", us,
                         f"s_per_sample={t*1e3:.1f}ms batch={b} "
                         f"speedup_vs_DPfp32={(base/t - 1)*100:+.0f}%"))
        rows.append((f"fig16/{fabric}/checks", us, " ".join(checks)))
    return rows
