"""Table IV: the composed-fabric link matrix (bandwidth, latency, ratios)."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.topology import (DEFAULT_LINKS, PAPER_FF_BW, PAPER_FL_BW,
                                 PAPER_LL_BW, LinkClass)


def run() -> List[Tuple[str, float, str]]:
    rows = []
    t0 = time.perf_counter()
    ll = DEFAULT_LINKS[LinkClass.LOCAL]
    ff = DEFAULT_LINKS[LinkClass.SWITCH]
    fl = DEFAULT_LINKS[LinkClass.HOST]
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("table4/L-L", us,
                 f"bw={ll.bandwidth/1e9:.2f}GB/s lat={ll.latency*1e6:.2f}us "
                 f"(paper {PAPER_LL_BW}GB/s NVLink -> TPU ICI)"))
    rows.append(("table4/F-F", us,
                 f"bw={ff.bandwidth/1e9:.2f}GB/s lat={ff.latency*1e6:.2f}us "
                 f"ratio_vs_LL={ff.bandwidth/ll.bandwidth:.3f} "
                 f"(paper {PAPER_FF_BW/PAPER_LL_BW:.3f})"))
    rows.append(("table4/F-L", us,
                 f"bw={fl.bandwidth/1e9:.2f}GB/s lat={fl.latency*1e6:.2f}us "
                 f"ratio_vs_LL={fl.bandwidth/ll.bandwidth:.3f} "
                 f"(paper {PAPER_FL_BW/PAPER_LL_BW:.3f})"))
    ok = (ll.bandwidth > ff.bandwidth > fl.bandwidth)
    rows.append(("table4/ordering", us,
                 f"LL>FF>FL={'OK' if ok else 'VIOLATED'}"))
    return rows
