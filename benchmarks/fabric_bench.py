"""Fabric-topology scaling bench: one job, three wiring models.

The GigaIO-style experiment ("Scaling to 32 GPUs on a Novel Composable
System Architecture"): the *same* training job composed at 4 / 8 / 16 /
32 devices on a drawer-structured switch pool, priced under each
registered fabric topology (``repro.core.fabrics``).  Every point runs
the full control-plane stack — admission (topology-aware candidate
ranking), clique-major placement, compose, and path-aware repricing —
so the curve measures what the scheduler would actually deliver, not a
formula evaluated in isolation.

Per point we report the repriced step time and the strong-scaling
efficiency ``(T(4) / T(n)) / (n / 4)``; the acceptance block pins the
two headline facts:

  * ``single_switch`` through the pluggable topology is **bit-identical**
    to the legacy flat fabric (the ``topology=None`` pool) at every size;
  * the oversubscribed spine shows a knee — >= 10 points of efficiency
    lost vs ``single_switch`` at 32 devices, once 8 chips per drawer
    share a 2-chip-wide uplink.

Artifact: ``results/fabric_bench.json`` (schema in docs/artifacts.md);
trajectory: ``results/BENCH_fabric_bench.json`` (scaling-efficiency
metrics gated direction-aware by scripts/check_perf.py).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

from repro.cluster.scheduler import Job, Scheduler
from repro.core.fabrics import make_topology
from repro.core.topology import (DEFAULT_LINKS, LinkClass, Topology,
                                 make_pool)

ARCH = "qwen2-0.5b"
SHAPE = "train_4k"
SIZES = (4, 8, 16, 32)
N_DRAWERS = 4
DRAWER_CHIPS = 8                      # 4 drawers x 8 switch-attached chips

TOPOLOGY_PARAMS: Dict[str, Dict[str, object]] = {
    "single_switch": {},
    "pcie_cascade": {"tiers": 1, "bw_taper": 0.7},
    "oversubscribed_spine": {"oversubscription": 4.0, "leaf_ports": 8},
}

# efficiency below this marks the curve's knee (first such size)
KNEE_EFF = 0.9


def _measure(topology: Optional[Topology], n: int) -> Dict[str, object]:
    """Admit + place + compose one ``n``-chip job; return its priced point."""
    pool = make_pool(n_local=0, n_switch=N_DRAWERS * DRAWER_CHIPS,
                     pods=N_DRAWERS, topology=topology)
    sched = Scheduler(pool)
    job = Job(f"fb-{n}", ARCH, SHAPE, n_chips=n, steps=1)
    if not sched.submit(job, 0.0):
        raise RuntimeError(f"fabric_bench job rejected: {job.why_rejected}")
    sched.poll(0.0)
    if job.system is None:
        raise RuntimeError(f"fabric_bench job did not start at n={n}")
    fab = job.system.fabric
    return {
        "devices": n,
        "mesh": "x".join(str(s) for s in job.system.axis_sizes),
        "step_s": job.plan.step_s,
        "terms": {k: v for k, v in job.plan.terms.items()},
        "axis_links": {a: c.value for a, c in fab.axis_links.items()},
        "axis_hops": {a: fab.hops(a) for a in fab.axis_links},
        "axis_bw_scale": {a: fab.axis_bw_scale.get(a, 1.0)
                          for a in fab.axis_links},
    }


def _curve(topology: Optional[Topology]) -> List[Dict[str, object]]:
    points = [_measure(topology, n) for n in SIZES]
    t4 = points[0]["step_s"]
    for p in points:
        ideal = p["devices"] / SIZES[0]
        p["efficiency"] = (t4 / p["step_s"]) / ideal
    return points


def _knee(points: List[Dict[str, object]]) -> Optional[int]:
    for p in points:
        if p["efficiency"] < KNEE_EFF:
            return int(p["devices"])
    return None


def _cross_domain_never_beats_dcn() -> bool:
    """Pairwise invariant sweep over a mixed local+switch pool: every
    cross-domain path either stays on the composed switch fabric (which
    physically spans drawers) or is priced no faster than the DCN."""
    dcn_bw = DEFAULT_LINKS[LinkClass.DCN].bandwidth
    for name, params in TOPOLOGY_PARAMS.items():
        topo = make_topology(name, **params)
        pool = make_pool(n_local=8, n_switch=8, pods=2, topology=topo)
        for a in pool.devices:
            for b in pool.devices:
                if a.domain == b.domain:
                    continue
                link, _hops = pool.path(a, b)
                if link.cls != LinkClass.SWITCH and link.bandwidth > dcn_bw:
                    return False
    return True


# Perf-trajectory spec for results/BENCH_fabric_bench.json: the scaling
# efficiencies are deterministic model outputs — gated direction-aware
# so a model change that silently degrades (or inflates) a curve fails
# CI; the knee contrast is recorded info-only.
TRAJECTORY = {
    "single_switch_eff_32": {"direction": "up"},
    "pcie_cascade_eff_32": {"direction": "up"},
    "oversubscribed_spine_eff_32": {"direction": "up"},
    "single_switch_step32_s": {"direction": "down"},
    "oversub_knee_drop_32": {"direction": "info"},
}


def trajectory_row(rep: Dict[str, object]) -> Dict[str, float]:
    """Flatten one report() into the gated summary-row metrics."""
    eff32 = {name: curve[-1]["efficiency"]
             for name, curve in rep["curves"].items()}
    return {
        "single_switch_eff_32": eff32["single_switch"],
        "pcie_cascade_eff_32": eff32["pcie_cascade"],
        "oversubscribed_spine_eff_32": eff32["oversubscribed_spine"],
        "single_switch_step32_s":
            rep["curves"]["single_switch"][-1]["step_s"],
        "oversub_knee_drop_32": rep["acceptance"]["oversub_knee_drop_32"],
    }


def report() -> Dict[str, object]:
    curves = {name: _curve(make_topology(name, **params))
              for name, params in TOPOLOGY_PARAMS.items()}
    legacy = _curve(None)            # the pre-topology flat-fabric pool
    eff32 = {name: c[-1]["efficiency"] for name, c in curves.items()}
    knee_drop = eff32["single_switch"] - eff32["oversubscribed_spine"]
    return {
        "bench": "fabric_bench",
        "config": {
            "arch": ARCH, "shape": SHAPE, "sizes": list(SIZES),
            "drawers": N_DRAWERS, "chips_per_drawer": DRAWER_CHIPS,
            "topologies": TOPOLOGY_PARAMS, "knee_efficiency": KNEE_EFF,
        },
        "curves": curves,
        "knee_devices": {name: _knee(c) for name, c in curves.items()},
        "acceptance": {
            "single_switch_matches_flat_model": curves["single_switch"]
                == legacy,
            "oversub_knee_drop_32": knee_drop,
            "oversub_knee_ge_10pct": knee_drop >= 0.10,
            "cross_domain_never_beats_dcn":
                _cross_domain_never_beats_dcn(),
        },
    }


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    rep = report()
    us = (time.perf_counter() - t0) * 1e6
    acc = rep["acceptance"]
    rows = []
    for name, curve in rep["curves"].items():
        effs = " ".join(f"{p['devices']}:{p['efficiency']:.3f}"
                        for p in curve)
        knee = rep["knee_devices"][name]
        rows.append((f"fabric_bench/{name}", us,
                     f"eff {effs} knee={knee or '-'}"))
    ok = (acc["single_switch_matches_flat_model"]
          and acc["oversub_knee_ge_10pct"]
          and acc["cross_domain_never_beats_dcn"])
    rows.append(("fabric_bench/acceptance", us,
                 f"flat_match={acc['single_switch_matches_flat_model']} "
                 f"knee_drop={acc['oversub_knee_drop_32']:.3f} "
                 f"no_fast_cross_domain="
                 f"{acc['cross_domain_never_beats_dcn']} "
                 f"{'OK' if ok else 'FAIL'}"))
    return rows
