"""Recompose benchmark: live mid-job attach / detach / migrate.

Four deterministic scenarios over the live recomposition plane
(``repro.cluster.recomposer``), one artifact
(``results/recompose_bench.json``; schema in ``docs/artifacts.md``):

  * **legacy identity** — the cluster_sim base trace (``recompose=None``)
    replayed twice must produce bit-identical reports with no
    ``recompose`` section and no attach/detach/migrate events: the
    plane is free when unused.
  * **shrink-to-admit (skew)** — two wide elastic trainers flood the
    pool; a wave of small jobs plus one medium job queues behind them.
    The recomposer halves a donor so the queue admits immediately and
    the projected makespan improves — both the makespan *and* the mean
    queue wait must beat the fixed-composition baseline strictly.
  * **attach after repair (chaos)** — a failure wave shrinks an elastic
    trainer to half width; the legacy repair path returns the devices
    but never re-widens the job.  The recomposer attaches the repaired
    capacity (priced: it only fires because the projected completion
    beats staying narrow net of the checkpoint restore), cutting the
    makespan roughly in half.
  * **tranche migrate** — an input-bound elastic trainer shares an NVMe
    tranche with two co-tenants while another tranche sits idle behind
    a finished blocker.  The recomposer re-attaches the drawer with the
    strictly better per-lessee bandwidth and the input stalls collapse.

A **determinism** check replays every recomposer-on scenario twice and
requires bit-identical reports (the tick is rng-free).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Tuple

from benchmarks.cluster_sim import BENCH_CFG
from repro.cluster.recomposer import RecomposeConfig
from repro.cluster.simulator import (ClusterSimulator, JobTemplate,
                                     TraceConfig)
from repro.core.topology import LinkClass
from repro.data.pipeline import IOWorkload
from repro.data.storage import StorageTranche

# Tick fast enough to catch the scripted windows; cooldown still long
# enough that no job is re-shaped on consecutive ticks.
RC = RecomposeConfig(interval_s=10.0, cooldown_s=20.0)

# -- shrink-to-admit: two wide elastic trainers + a queued small wave -----
_WIDE = JobTemplate("llama3.2-3b", "train_4k", 64, 100, elastic=True)
_SMALL = JobTemplate("qwen2-0.5b", "train_4k", 16, 10)
_MED = JobTemplate("qwen2-0.5b", "train_4k", 32, 30)

SKEW_ARRIVALS: Tuple[Tuple[float, JobTemplate], ...] = (
    ((0.0, _WIDE), (1.0, _WIDE))
    + tuple((40.0 + i, _SMALL) for i in range(8))
    + ((60.0, _MED),))

SKEW_CFG = TraceConfig(n_jobs=0, n_local=64, n_switch=64, pods=2,
                       failures=(), arrivals=SKEW_ARRIVALS)

# -- attach after repair: failure wave shrinks, legacy repair idles -------
# The pool gives one 64-chip local domain (n_local=128, pods=2), so the
# re-widened mesh is as fast as the admission-time one; the failure wave
# is large enough that the trainer cannot re-fit at full width and
# shrinks in place instead of restarting.
_CHAOS_JOB = JobTemplate("llama3.2-3b", "train_4k", 64, 200, elastic=True)

CHAOS_CFG = TraceConfig(n_jobs=0, n_local=128, n_switch=16, pods=2,
                        failures=((30.0, 85),), repair_after_s=60.0,
                        arrivals=((1.0, _CHAOS_JOB),))

# -- tranche migrate: contended drawer vs an idle one ---------------------
def _io(name: str, dataset_tb: float, batch: int = 2048) -> IOWorkload:
    return IOWorkload(name, 1e6, 0.0, batch, int(dataset_tb * 1e6))

# nvme-0 is sized so the blocker's dataset fills it: every later job
# lands on nvme-1 at admission, and only the blocker's completion frees
# the idle drawer the recomposer can migrate onto.
_BLOCKER = JobTemplate("qwen2-0.5b", "train_4k", 16, 40,
                       io=_io("blocker", 1.0))
_IO_ELASTIC = JobTemplate("qwen2-0.5b", "train_4k", 16, 400, elastic=True,
                          io=_io("elastic", 0.5))
_IO_SMALL = JobTemplate("qwen2-0.5b", "train_4k", 16, 150,
                        io=_io("small", 0.3))

MIGRATE_CFG = TraceConfig(
    n_jobs=0, n_local=64, n_switch=64, pods=2, failures=(),
    storage_tranches=(
        StorageTranche("nvme-0", capacity_bytes=1.2e12,
                       attach=LinkClass.LOCAL, domain=0),
        StorageTranche("nvme-1", capacity_bytes=4e12,
                       attach=LinkClass.LOCAL, domain=0)),
    arrivals=((0.0, _BLOCKER), (2.0, _IO_ELASTIC),
              (3.0, _IO_SMALL), (4.0, _IO_SMALL)))


# Perf-trajectory spec for results/BENCH_recompose_bench.json (see
# docs/tracking.md).  All metrics come from fixed-seed deterministic
# replays, so the gate is machine-independent.
TRAJECTORY = {
    "skew_makespan_s": {"direction": "down"},
    "skew_wait_mean_s": {"direction": "down"},
    "skew_makespan_gain_s": {"direction": "up"},
    "skew_wait_gain_s": {"direction": "up"},
    "chaos_makespan_gain_s": {"direction": "up"},
    "migrate_makespan_gain_s": {"direction": "up"},
    "recompose_actions": {"direction": "info"},
    "legacy_identical": {"direction": "up"},
    "deterministic": {"direction": "up"},
}


def trajectory_row(rep: Dict[str, object]) -> Dict[str, float]:
    """Flatten one report() into the gated summary-row metrics."""
    acc = rep["acceptance"]
    sk = rep["scenarios"]["skew"]
    return {
        "skew_makespan_s": sk["recompose"]["makespan_s"],
        "skew_wait_mean_s": sk["recompose"]["job_wait_mean_s"],
        "skew_makespan_gain_s": acc["skew_makespan_gain_s"],
        "skew_wait_gain_s": acc["skew_wait_gain_s"],
        "chaos_makespan_gain_s": acc["chaos_makespan_gain_s"],
        "migrate_makespan_gain_s": acc["migrate_makespan_gain_s"],
        "recompose_actions": float(rep["actions_total"]),
        "legacy_identical": float(acc["legacy_identical"]),
        "deterministic": float(acc["deterministic"]),
    }


def _canon(rep: Dict[str, object]) -> str:
    return json.dumps(rep, sort_keys=True, default=str)


def _pair(cfg: TraceConfig) -> Tuple[Dict[str, object], Dict[str, object]]:
    """One scenario replayed without and with the recomposition plane."""
    base = ClusterSimulator(cfg).run()
    rc = ClusterSimulator(dataclasses.replace(cfg, recompose=RC)).run()
    return base, rc


def _trim(rep: Dict[str, object]) -> Dict[str, object]:
    """The fields the artifact keeps per scenario leg."""
    out = {
        "makespan_s": rep["makespan_s"],
        "job_wait_mean_s": rep["job_wait_s"]["mean"],
        "jobs": rep["jobs"],
        "recomposition": rep["recomposition"],
    }
    if "recompose" in rep:
        out["recompose"] = rep["recompose"]
    return out


def report() -> Dict[str, object]:
    # legacy identity: recompose=None twice, bit-identical, no new keys
    legacy_a = ClusterSimulator(BENCH_CFG).run()
    legacy_b = ClusterSimulator(BENCH_CFG).run()
    legacy_identical = (
        _canon(legacy_a) == _canon(legacy_b)
        and "recompose" not in legacy_a)

    skew_base, skew_rc = _pair(SKEW_CFG)
    chaos_base, chaos_rc = _pair(CHAOS_CFG)
    mig_base, mig_rc = _pair(MIGRATE_CFG)

    # determinism: every recomposer-on leg replayed bit-identically
    deterministic = all(
        _canon(ClusterSimulator(
            dataclasses.replace(cfg, recompose=RC)).run()) == _canon(rc)
        for cfg, rc in ((SKEW_CFG, skew_rc), (CHAOS_CFG, chaos_rc),
                        (MIGRATE_CFG, mig_rc)))

    scen = {
        "skew": {"base": _trim(skew_base), "recompose": _trim(skew_rc)},
        "chaos": {"base": _trim(chaos_base), "recompose": _trim(chaos_rc)},
        "migrate": {"base": _trim(mig_base), "recompose": _trim(mig_rc)},
    }
    actions = sum(
        leg["recompose"]["attaches"] + leg["recompose"]["detaches"]
        + leg["recompose"]["migrations"]
        for leg in (scen[s]["recompose"] for s in scen))
    rep: Dict[str, object] = {
        "bench": "recompose_bench",
        "legacy_identical": legacy_identical,
        "deterministic": deterministic,
        "actions_total": actions,
        "scenarios": scen,
    }
    sk_b, sk_r = scen["skew"]["base"], scen["skew"]["recompose"]
    ch_b, ch_r = scen["chaos"]["base"], scen["chaos"]["recompose"]
    mg_b, mg_r = scen["migrate"]["base"], scen["migrate"]["recompose"]
    rep["acceptance"] = {
        "legacy_identical": legacy_identical,
        "deterministic": deterministic,
        "skew_makespan_gain_s":
            sk_b["makespan_s"] - sk_r["makespan_s"],
        "skew_wait_gain_s":
            sk_b["job_wait_mean_s"] - sk_r["job_wait_mean_s"],
        "skew_strictly_better":
            sk_r["makespan_s"] < sk_b["makespan_s"]
            and sk_r["job_wait_mean_s"] < sk_b["job_wait_mean_s"],
        "skew_detaches": sk_r["recompose"]["detaches"],
        "chaos_makespan_gain_s":
            ch_b["makespan_s"] - ch_r["makespan_s"],
        "chaos_attaches": ch_r["recompose"]["attaches"],
        "chaos_rejoins_repaired_capacity":
            ch_r["recompose"]["attaches"] >= 1
            and ch_r["recompose"]["devices_recomposed"] > 0
            and ch_r["makespan_s"] < ch_b["makespan_s"],
        "migrate_makespan_gain_s":
            mg_b["makespan_s"] - mg_r["makespan_s"],
        "migrate_migrations": mg_r["recompose"]["migrations"],
        "migrate_strictly_better":
            mg_r["recompose"]["migrations"] >= 1
            and mg_r["makespan_s"] < mg_b["makespan_s"],
        "no_jobs_lost": all(
            leg["jobs"]["failed"] == 0 and leg["jobs"]["stranded"] == 0
            for s in scen for leg in scen[s].values()),
    }
    return rep


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    rep = report()
    us = (time.perf_counter() - t0) * 1e6
    acc = rep["acceptance"]
    ok = (acc["legacy_identical"] and acc["deterministic"]
          and acc["skew_strictly_better"]
          and acc["chaos_rejoins_repaired_capacity"]
          and acc["migrate_strictly_better"] and acc["no_jobs_lost"])
    return [
        ("recompose_bench/legacy", us,
         f"recompose=None bit-identical, no new keys: "
         f"{'OK' if acc['legacy_identical'] else 'FAIL'}"),
        ("recompose_bench/skew", us,
         f"makespan_gain={acc['skew_makespan_gain_s']:.1f}s "
         f"wait_gain={acc['skew_wait_gain_s']:.1f}s "
         f"detaches={acc['skew_detaches']} "
         f"{'OK' if acc['skew_strictly_better'] else 'FAIL'}"),
        ("recompose_bench/chaos", us,
         f"makespan_gain={acc['chaos_makespan_gain_s']:.1f}s "
         f"attaches={acc['chaos_attaches']} "
         f"{'OK' if acc['chaos_rejoins_repaired_capacity'] else 'FAIL'}"),
        ("recompose_bench/migrate", us,
         f"makespan_gain={acc['migrate_makespan_gain_s']:.1f}s "
         f"migrations={acc['migrate_migrations']} "
         f"{'OK' if acc['migrate_strictly_better'] else 'FAIL'}"),
        ("recompose_bench/determinism", us,
         f"replays bit-identical: "
         f"{'OK' if acc['deterministic'] else 'FAIL'} "
         f"actions={rep['actions_total']} "
         f"{'OK' if ok else 'FAIL'}"),
    ]
