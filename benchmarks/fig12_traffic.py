"""Fig 12: sustained traffic (GB/s) on the composed fabric per benchmark.

Paper: BERT-large 76.43 GB/s ~= 19x MobileNetV2 (4 GB/s), ~7x ResNet-50
(11.31 GB/s).  The quantity is gradient-exchange bytes per wall-second, so
it is a *joint* property of model size and step time — reproduced here
from the same analytic step model as Fig 11.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.paper_model import PAPER_WORKLOADS, fabric_traffic_gbps

PAPER_GBPS = {"mobilenetv2": 4.0, "resnet50": 11.31, "bert-large": 76.43}


def run() -> List[Tuple[str, float, str]]:
    rows = []
    vals = {}
    for w in PAPER_WORKLOADS:
        t0 = time.perf_counter()
        g = fabric_traffic_gbps(w, "falconGPUs")
        us = (time.perf_counter() - t0) * 1e6
        vals[w.name] = g
        note = ""
        if w.name in PAPER_GBPS:
            note = f" paper={PAPER_GBPS[w.name]:.1f}GB/s"
        rows.append((f"fig12/{w.name}", us, f"traffic={g:.2f}GB/s{note}"))
    r_bl_mb = vals["bert-large"] / vals["mobilenetv2"]
    r_bl_rn = vals["bert-large"] / vals["resnet50"]
    rows.append(("fig12/ratios", 0.0,
                 f"BL/MBv2={r_bl_mb:.1f}x (paper ~19x) "
                 f"BL/RN50={r_bl_rn:.1f}x (paper ~7x) "
                 f"ordering={'OK' if vals['mobilenetv2'] < vals['resnet50'] < vals['bert-large'] else 'FAIL'}"))
    return rows
