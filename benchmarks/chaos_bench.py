"""Chaos benchmark: fault injection, recovery, and serving resilience.

Four deterministic scenarios over the cluster simulator's fault plane
(``repro.cluster.faults``), one artifact
(``results/chaos_bench.json``; schema in ``docs/artifacts.md``):

  * **baseline identity** — the cluster_sim base trace replayed with
    ``faults=None`` and with an *empty* ``FaultPlan()`` must produce
    bit-identical reports: the fault plane is free when unused.
  * **domain outage** — a whole locality domain (one side of the PCIe
    switch fabric — the composable-infra failure unit) drops mid-trace
    and is repaired a minute later.  Retry-with-backoff restarts every
    surviving job; availability stays above 0.9 and nothing strands.
  * **graceful degradation** — the switch and DCN link classes lose
    half their bandwidth and an NVMe tranche browns out.  Nobody is
    evicted: jobs
    are repriced through the incremental accumulators and finish at the
    degraded rate (longer makespan, zero preemptions).
  * **serve failover** — a replica-killing device fault lands mid
    request-burst.  With per-request timeouts + retries + health-check
    failover the failed-request rate stays under 1%; with retries off
    the requests on the dead replica hang unboundedly (stranded or
    failed, never completed).

A fifth *churn* scenario (MTBF-seeded ``device_down`` waves) supplies
the headline availability / goodput / recovery-time distributions for
the perf trajectory.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Tuple

from benchmarks.cluster_sim import BENCH_CFG
from repro.cluster.faults import FaultPlan, FaultSpec
from repro.cluster.simulator import (ClusterSimulator, ServiceConfig,
                                     TraceConfig)

# -- domain outage: one drawer of the 2-pod pool gone for 60 s ------------
OUTAGE_CFG = dataclasses.replace(
    BENCH_CFG, failures=(),
    faults=FaultPlan(
        faults=(FaultSpec(kind="domain_outage", t=120.0, domain=1,
                          t_clear=150.0, detect_s=2.0),),
        retry_backoff_s=5.0))

# -- graceful degradation: link at 50%, first tranche at 25% --------------
DEGRADE_CFG = dataclasses.replace(
    BENCH_CFG, failures=(),
    faults=FaultPlan(faults=(
        FaultSpec(kind="link_degrade", t=60.0, link="switch", frac=0.5,
                  t_clear=300.0),
        # the cross-domain pricing fix moved the base trace's critical
        # path onto the DCN; degrade it too so the scenario still
        # stretches the makespan instead of hiding behind that job
        FaultSpec(kind="link_degrade", t=60.0, link="dcn", frac=0.5,
                  t_clear=300.0),
        FaultSpec(kind="tranche_brownout", t=90.0, tranche="local-nvme-0",
                  frac=0.25, t_clear=240.0),
    )))

# -- MTBF churn: repeated partial-pool failure waves ----------------------
CHURN_CFG = TraceConfig(
    n_jobs=24, arrival_rate_hz=0.25, seed=7, failures=(),
    faults=FaultPlan(mtbf_s=90.0, mttr_s=60.0, horizon_s=360.0,
                     mtbf_n=48, detect_s=2.0, retry_backoff_s=5.0))

# -- serve burst + replica-killing fault ----------------------------------
_SERVE_FAULT = FaultPlan(faults=(
    FaultSpec(kind="device_down", t=30.0, n=64, t_clear=200.0,
              detect_s=10.0),))


def _serve_cfg(*, retries: int, health_s: float,
               timeout_s: float) -> TraceConfig:
    return TraceConfig(
        n_jobs=0, seed=11, failures=(),
        services=(ServiceConfig(
            name="chat", arch="llama3.2-3b", shape_name="decode_32k",
            n_replicas=3, chips_per_replica=64, n_requests=160,
            arrival_rate_hz=4.0, prompt_len=2048, max_new=128,
            request_timeout_s=timeout_s, max_request_retries=retries,
            retry_backoff_s=0.5, health_check_s=health_s),),
        faults=_SERVE_FAULT)


SERVE_RESILIENT_CFG = _serve_cfg(retries=2, health_s=2.0, timeout_s=15.0)
SERVE_NO_RETRY_CFG = _serve_cfg(retries=0, health_s=0.0, timeout_s=15.0)
SERVE_NO_RESILIENCE_CFG = _serve_cfg(retries=0, health_s=0.0, timeout_s=0.0)


# Perf-trajectory spec for results/BENCH_chaos_bench.json (see
# docs/tracking.md).  All metrics come from fixed-seed deterministic
# replays, so the gate is machine-independent.
TRAJECTORY = {
    "availability": {"direction": "up"},
    "goodput_fraction": {"direction": "up"},
    "recovery_mean_s": {"direction": "down"},
    "recovery_p95_s": {"direction": "down"},
    "outage_availability": {"direction": "up"},
    "serve_failed_request_rate": {"direction": "down"},
    "baseline_identical": {"direction": "up"},
}


def trajectory_row(rep: Dict[str, object]) -> Dict[str, float]:
    """Flatten one report() into the gated summary-row metrics."""
    return {
        "availability": rep["availability"],
        "goodput_fraction": rep["goodput_fraction"],
        "recovery_mean_s": rep["recovery"]["mean_s"],
        "recovery_p95_s": rep["recovery"]["p95_s"],
        "outage_availability":
            rep["scenarios"]["domain_outage"]["faults"]["availability"],
        "serve_failed_request_rate":
            rep["serve"]["resilient"]["failed_request_rate"],
        "baseline_identical": float(rep["baseline_identical"]),
    }


def _canon(rep: Dict[str, object]) -> str:
    return json.dumps(rep, sort_keys=True, default=str)


def _scenario(cfg: TraceConfig) -> Dict[str, object]:
    """One fault scenario, trimmed to the fields the artifact keeps."""
    rep = ClusterSimulator(cfg).run()
    return {
        "jobs": rep["jobs"],
        "faults": rep["faults"],
        "makespan_s": rep["makespan_s"],
        "recomposition": rep["recomposition"],
    }


def _serve_scenario(cfg: TraceConfig) -> Dict[str, object]:
    rep = ClusterSimulator(cfg).run()
    sv = rep["serving"]["chat"]
    return {
        "requests": sv["requests"],
        "failed_request_rate": sv["failed_request_rate"],
        "availability": rep["faults"]["availability"],
    }


def report() -> Dict[str, object]:
    base = ClusterSimulator(BENCH_CFG).run()
    empty = ClusterSimulator(dataclasses.replace(
        BENCH_CFG, faults=FaultPlan())).run()
    identical = _canon(base) == _canon(empty)
    # the degradation scenarios drop the legacy failure wave, so their
    # makespan reference is the same trace with no faults at all
    clean = ClusterSimulator(dataclasses.replace(
        BENCH_CFG, failures=())).run()

    outage = _scenario(OUTAGE_CFG)
    degrade = _scenario(DEGRADE_CFG)
    churn = _scenario(CHURN_CFG)
    serve_res = _serve_scenario(SERVE_RESILIENT_CFG)
    serve_noretry = _serve_scenario(SERVE_NO_RETRY_CFG)
    serve_none = _serve_scenario(SERVE_NO_RESILIENCE_CFG)

    base_makespan = clean["makespan_s"]
    rep: Dict[str, object] = {
        "bench": "chaos_bench",
        "baseline_identical": identical,
        # headline resilience numbers (MTBF churn scenario)
        "availability": churn["faults"]["availability"],
        "goodput_fraction": churn["faults"]["goodput_fraction"],
        "recovery": churn["faults"]["recovery"],
        "detect_s_mean": churn["faults"]["detect_s_mean"],
        "scenarios": {
            "domain_outage": outage,
            "degradation": degrade,
            "churn": churn,
        },
        "serve": {
            "resilient": serve_res,
            "no_retries": serve_noretry,
            "no_resilience": serve_none,
        },
    }
    out_jobs = outage["jobs"]
    rep["acceptance"] = {
        "baseline_identical": identical,
        "outage_availability": outage["faults"]["availability"],
        "outage_availability_above_0_9":
            outage["faults"]["availability"] > 0.9,
        "outage_all_jobs_recovered":
            out_jobs["failed"] == 0 and out_jobs["stranded"] == 0
            and out_jobs["completed"] + out_jobs["rejected"]
            == out_jobs["submitted"],
        "degradation_graceful":
            degrade["jobs"]["preempted"] == 0
            and degrade["jobs"]["evicted"] == 0
            and degrade["jobs"]["failed"] == 0
            and degrade["makespan_s"] >= base_makespan,
        "degradation_makespan_stretch_s":
            degrade["makespan_s"] - base_makespan,
        "churn_recovery_samples": churn["faults"]["recovery"]["samples"],
        "serve_failed_rate_resilient": serve_res["failed_request_rate"],
        "serve_failed_rate_below_1pct":
            serve_res["failed_request_rate"] < 0.01,
        "serve_unbounded_without_retries":
            (serve_noretry["failed_request_rate"]
             > serve_res["failed_request_rate"])
            or serve_none["requests"]["stranded"] > 0,
    }
    return rep


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    rep = report()
    us = (time.perf_counter() - t0) * 1e6
    acc = rep["acceptance"]
    rec = rep["recovery"]
    ok = (acc["baseline_identical"]
          and acc["outage_availability_above_0_9"]
          and acc["outage_all_jobs_recovered"]
          and acc["degradation_graceful"]
          and acc["serve_failed_rate_below_1pct"]
          and acc["serve_unbounded_without_retries"])
    sv = rep["serve"]
    return [
        ("chaos_bench/baseline", us,
         f"faults=None == FaultPlan(): "
         f"{'OK' if acc['baseline_identical'] else 'FAIL'}"),
        ("chaos_bench/outage", us,
         f"availability={acc['outage_availability']:.3f} "
         f"recovered={'OK' if acc['outage_all_jobs_recovered'] else 'FAIL'}"),
        ("chaos_bench/degradation", us,
         f"makespan_stretch={acc['degradation_makespan_stretch_s']:.0f}s "
         f"graceful={'OK' if acc['degradation_graceful'] else 'FAIL'}"),
        ("chaos_bench/churn", us,
         f"availability={rep['availability']:.3f} "
         f"goodput={rep['goodput_fraction']:.3f} "
         f"recovery mean={rec['mean_s']:.1f}s p95={rec['p95_s']:.1f}s "
         f"({rec['samples']} samples)"),
        ("chaos_bench/serve", us,
         f"failed_rate resilient="
         f"{sv['resilient']['failed_request_rate']:.4f} "
         f"no_retries={sv['no_retries']['failed_request_rate']:.4f} "
         f"stranded_no_resilience="
         f"{sv['no_resilience']['requests']['stranded']} "
         f"{'OK' if ok else 'FAIL'}"),
    ]
