"""Fig 10/13/14: analytic utilization profile per benchmark x config.

The paper's wandb plots show GPU util > 80% for all benchmarks, slightly
HIGHER GPU util on falcon configs (the GPU waits on the fabric inside the
NCCL kernel, which counts as "busy"), vision stressing host CPUs (input
pre-processing), NLP stressing device memory.  We derive the analogous
analytic occupancies from the same step model.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.paper_model import (PAPER_WORKLOADS, comm_time,
                                    compute_time, step_time)
from repro.data import IO_WORKLOADS, StorageModel
from repro.core.topology import LOCAL_NVME


def run() -> List[Tuple[str, float, str]]:
    rows = []
    storage = StorageModel(LOCAL_NVME)
    for w in PAPER_WORKLOADS:
        t0 = time.perf_counter()
        out = {}
        for config in ("localGPUs", "falconGPUs"):
            comp = compute_time(w)
            step = step_time(w, config)
            # device busy = compute + in-kernel collective wait (the NCCL
            # kernel spins on the fabric and counts as GPU-busy — exactly
            # why the paper sees *higher* util on falcon configs)
            busy = comp + comm_time(w, config)
            out[config] = min(1.0, busy / step)
        read = storage.read_time(
            w.batch_size * IO_WORKLOADS[w.name].record_bytes)
        cpu_util = min(1.0, (read * 3.0) / step_time(w, "localGPUs"))
        us = (time.perf_counter() - t0) * 1e6
        ok80 = all(v > 0.6 for v in out.values())
        rows.append((f"fig10/{w.name}", us,
                     f"gpu_util_local={out['localGPUs']*100:.0f}% "
                     f"falcon={out['falconGPUs']*100:.0f}% "
                     f"cpu_input_util={cpu_util*100:.0f}% "
                     f"(paper: >80% util, falcon >= local) "
                     f"falcon>=local:"
                     f"{'OK' if out['falconGPUs'] >= out['localGPUs'] - 1e-9 else 'FAIL'}"))
    return rows
