"""Benchmark harness: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig11]
Prints ``name,us_per_call,derived`` CSV per row.
"""
from __future__ import annotations

import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (beyond_paper, fig10_utilization,
                            fig11_switch_overhead, fig12_traffic,
                            fig15_storage, fig16_sw_opt, recompose,
                            roofline, table2_models, table4_links)
    modules = {
        "table2": table2_models,
        "table4": table4_links,
        "fig10": fig10_utilization,
        "fig11": fig11_switch_overhead,
        "fig12": fig12_traffic,
        "fig15": fig15_storage,
        "fig16": fig16_sw_opt,
        "beyond": beyond_paper,
        "recompose": recompose,
        "roofline": roofline,
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in modules.items():
        if args.only and args.only != name:
            continue
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}",
                  file=sys.stdout)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
