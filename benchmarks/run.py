"""Benchmark harness: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig11]
Prints ``name,us_per_call,derived`` CSV per row.

``--bench <name>`` runs one module and, when it exposes ``report()``,
emits the JSON artifact to stdout and ``results/<name>.json``.  Every
``--bench`` invocation is a **tracked run** (``repro.tracking``): the
report is produced under an active ``tracking.init(...)`` scope (so the
simulator/engine mirror their telemetry into the run's
``events.jsonl``), the artifact is stamped with ``schema_version`` and
``run_id``, and — when the module declares a ``TRAJECTORY`` metric spec
plus ``trajectory_row()`` — exactly one summary row is appended to
``results/BENCH_<name>.json`` for ``scripts/check_perf.py`` to gate.
Pass ``--no-track`` to skip tracking (pure artifact regeneration).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ARTIFACT_SCHEMA_VERSION = 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--bench", default="",
                    help="run one module; write its JSON report artifact")
    ap.add_argument("--out-dir", default="results")
    ap.add_argument("--no-track", action="store_true",
                    help="skip run tracking / trajectory append")
    ap.add_argument("--run-id", default="",
                    help="override the tracked run id (idempotent "
                         "trajectory append per run id)")
    args = ap.parse_args()

    from benchmarks import (beyond_paper, chaos_bench, cluster_sim,
                            fabric_bench, fig10_utilization,
                            fig11_switch_overhead, fig12_traffic,
                            fig15_storage, fig16_sw_opt, kernel_tune,
                            recompose, recompose_bench, roofline,
                            serve_bench, storage_bench, table2_models,
                            table4_links)
    modules = {
        "table2": table2_models,
        "table4": table4_links,
        "fig10": fig10_utilization,
        "fig11": fig11_switch_overhead,
        "fig12": fig12_traffic,
        "fig15": fig15_storage,
        "fig16": fig16_sw_opt,
        "beyond": beyond_paper,
        "recompose": recompose,
        "recompose_bench": recompose_bench,
        "roofline": roofline,
        "chaos_bench": chaos_bench,
        "cluster_sim": cluster_sim,
        "fabric_bench": fabric_bench,
        "kernel_tune": kernel_tune,
        "serve_bench": serve_bench,
        "storage_bench": storage_bench,
    }

    if args.bench:
        mod = modules.get(args.bench)
        if mod is None:
            print(f"unknown bench {args.bench!r}; known: {sorted(modules)}",
                  file=sys.stderr)
            return 2
        if not hasattr(mod, "report"):
            print(f"bench {args.bench!r} has no report(); use --only",
                  file=sys.stderr)
            return 2

        run = None
        if not args.no_track:
            import repro.tracking as tracking
            run = tracking.init(
                args.bench, config={"bench": args.bench},
                tags=("bench",),
                dir=os.path.join(args.out_dir, "runs"),
                run_id=args.run_id or None,
                samplers=[tracking.ProcSampler()])
            run.log_system()

        try:
            rep = mod.report()
            rep["schema_version"] = ARTIFACT_SCHEMA_VERSION
            if run is not None:
                rep["run_id"] = run.id
                run.log_system()
                spec = getattr(mod, "TRAJECTORY", None)
                if spec is not None:
                    from repro.tracking import trajectory
                    row = mod.trajectory_row(rep)
                    run.log_summary(row)
                    trajectory.append_summary(
                        trajectory.path_for(args.bench, args.out_dir),
                        args.bench, spec, run_id=run.id,
                        git_sha=run.git_sha, ts=run.clock(), metrics=row)
                    print(f"appended trajectory row {run.id} to "
                          f"{trajectory.path_for(args.bench, args.out_dir)}",
                          file=sys.stderr)
        except BaseException:
            if run is not None:
                run.finish("error")
            raise
        if run is not None:
            run.finish()

        out = json.dumps(rep, indent=2, default=str)
        print(out)
        os.makedirs(args.out_dir, exist_ok=True)
        path = os.path.join(args.out_dir, f"{args.bench}.json")
        with open(path, "w") as f:
            f.write(out + "\n")
        print(f"wrote {path}", file=sys.stderr)
        return 0

    print("name,us_per_call,derived")
    failed = 0
    for name, mod in modules.items():
        if args.only and args.only != name:
            continue
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}",
                  file=sys.stdout)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
