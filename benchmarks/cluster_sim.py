"""Cluster-sim benchmark: base trace + per-policy gang/fairness sweep.

Two layers, one artifact (``results/cluster_sim.json``; schema in
``docs/artifacts.md``):

  * **base** — the fixed-seed PR-1 trace (mixed train/prefill/decode
    jobs, one injected failure wave) under the default ``easy`` policy;
    its report fields sit at the artifact's top level and act as the
    control plane's perf-trajectory regression anchor (the scheduling
    order is pinned by ``tests/test_policies.py``).
  * **policies** — a scripted skewed-tenant scenario (one flooding
    tenant, two light tenants, one high-priority 2-pod gang) replayed
    under each of ``easy`` / ``fair_share`` / ``priority_preempt``.
    The ``acceptance`` block records the headline comparisons:
    fair_share cuts the mean per-tenant p95 queue wait vs easy, and
    priority_preempt starts the gang sooner by evicting low-priority
    work.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

from repro.cluster import JobTemplate, TraceConfig
from repro.cluster.scheduler import POLICIES
from repro.cluster.simulator import ClusterSimulator

BENCH_CFG = TraceConfig(n_jobs=24, arrival_rate_hz=0.2, seed=7,
                        failures=((120.0, 12),), repair_after_s=180.0)

# Skewed-tenant + gang scenario: scripted arrivals (rng-free) on a
# 2-pod, 256-chip pool.  Tenant "heavy" floods 2x the pool's capacity
# at t=0; light tenants "blue"/"green" trickle in behind the backlog; a
# high-priority 2-pod gang (32 chips per member clique) arrives mid-
# flood.  Under plain FIFO the light tenants and the gang queue behind
# the whole flood — exactly the skew fair_share and priority_preempt
# exist to fix.
_HEAVY = JobTemplate("qwen2-0.5b", "train_4k", 32, 30, tenant="heavy")
_BLUE = JobTemplate("qwen2-0.5b", "train_4k", 32, 6, tenant="blue")
_GREEN = JobTemplate("qwen2-0.5b", "train_4k", 32, 6, tenant="green")
_GANG = JobTemplate("qwen2-0.5b", "train_4k", 64, 10, n_pods=2,
                    tenant="gang", priority=5)

SKEW_ARRIVALS: Tuple[Tuple[float, JobTemplate], ...] = (
    tuple((float(i), _HEAVY) for i in range(16))
    + ((18.0, _GANG),)
    + tuple((20.0 + i, _BLUE) for i in range(3))
    + tuple((22.0 + i, _GREEN) for i in range(3)))

SKEW_CFG = TraceConfig(n_jobs=0, seed=0, n_local=128, n_switch=128, pods=2,
                       failures=(), arrivals=SKEW_ARRIVALS)


# Perf-trajectory spec for results/BENCH_cluster_sim.json (see
# docs/tracking.md).  Everything but sim_events_per_s is derived from the
# deterministic fixed-seed replay, so the gated values are machine-
# independent; the event rate is wall-clock and recorded info-only.
TRAJECTORY = {
    "makespan_s": {"direction": "down"},
    "pool_utilization": {"direction": "up"},
    "auu": {"direction": "down"},
    "job_wait_p99_s": {"direction": "down"},
    "job_wait_mean_s": {"direction": "down"},
    "fair_share_tenant_p95_wait_mean_s": {"direction": "down"},
    "priority_preempt_gang_p95_wait_s": {"direction": "down"},
    "sim_events_per_s": {"direction": "info"},
}


def trajectory_row(rep: Dict[str, object]) -> Dict[str, float]:
    """Flatten one report() into the gated summary-row metrics."""
    acc = rep["acceptance"]
    return {
        "makespan_s": rep["makespan_s"],
        "pool_utilization": rep["pool_utilization"],
        "auu": rep["auu"],
        "job_wait_p99_s": rep["job_wait_s"]["p99"],
        "job_wait_mean_s": rep["job_wait_s"]["mean"],
        "fair_share_tenant_p95_wait_mean_s":
            acc["fair_share_tenant_p95_wait_mean_s"],
        "priority_preempt_gang_p95_wait_s":
            acc["priority_preempt_gang_p95_wait_s"],
        "sim_events_per_s": rep["sim_events_per_s"],
    }


def policy_report(policy: str) -> Dict[str, object]:
    """The skewed-tenant gang scenario under one scheduling policy."""
    cfg = dataclasses.replace(SKEW_CFG, policy=policy)
    return ClusterSimulator(cfg).run()


def _gang_p95_wait(rep: Dict[str, object]) -> float:
    tenants = rep["fairness"]["tenants"]
    return tenants.get("gang", {"wait_s": {"p95": 0.0}})["wait_s"]["p95"]


def report() -> Dict[str, object]:
    sim = ClusterSimulator(BENCH_CFG)
    rep = sim.run()
    rep["bench"] = "cluster_sim"
    policies = {p: policy_report(p) for p in POLICIES}
    rep["policies"] = policies
    easy = policies["easy"]
    fair = policies["fair_share"]
    pre = policies["priority_preempt"]
    rep["acceptance"] = {
        "gangs_started_per_policy": {
            p: policies[p]["gangs"]["started"] for p in POLICIES},
        "easy_tenant_p95_wait_mean_s":
            easy["fairness"]["tenant_p95_wait_mean_s"],
        "fair_share_tenant_p95_wait_mean_s":
            fair["fairness"]["tenant_p95_wait_mean_s"],
        "fair_share_improves_tenant_p95_wait":
            fair["fairness"]["tenant_p95_wait_mean_s"]
            < easy["fairness"]["tenant_p95_wait_mean_s"],
        "easy_gang_p95_wait_s": _gang_p95_wait(easy),
        "priority_preempt_gang_p95_wait_s": _gang_p95_wait(pre),
        "priority_preempt_evictions": pre["jobs"]["evicted"],
        "priority_preempt_starts_gang_sooner":
            _gang_p95_wait(pre) < _gang_p95_wait(easy),
    }
    # wall-time telemetry lives here, not in the (deterministic) sim report
    rep["sim_wall_s"] = sim.wall_s
    rep["sim_events_per_s"] = sim.events_per_s
    return rep


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    rep = report()
    us = (time.perf_counter() - t0) * 1e6
    jobs = rep["jobs"]
    rec = rep["recomposition"]
    wait = rep["job_wait_s"]
    lt = rep["link_traffic_gb"]
    acc = rep["acceptance"]
    ok = (jobs["completed"] + jobs["rejected"] == jobs["submitted"]
          and jobs["stranded"] == 0 and rep["lease_conflicts"] == 0)
    policy_ok = (acc["fair_share_improves_tenant_p95_wait"]
                 and acc["priority_preempt_evictions"] >= 1
                 and all(n >= 1
                         for n in acc["gangs_started_per_policy"].values()))
    return [
        ("cluster_sim/jobs", us,
         f"submitted={jobs['submitted']} completed={jobs['completed']} "
         f"rejected={jobs['rejected']} preempted={jobs['preempted']} "
         f"stranded={jobs['stranded']} "
         f"conflicts={rep['lease_conflicts']} "
         f"{'OK' if ok else 'FAIL'}"),
        ("cluster_sim/utilization", us,
         f"pool_util={rep['pool_utilization']*100:.1f}% "
         f"AUU={rep['auu']*100:.1f}% "
         f"(AU={rep['accelerator_utilization']*100:.1f}%)"),
        ("cluster_sim/traffic", us,
         "per-link GB: " + " ".join(
             f"{k}={v:.0f}" for k, v in lt.items())),
        ("cluster_sim/recompose", us,
         f"count={rec['count']} overhead={rec['overhead_s']:.2f}s "
         f"({rec['overhead_frac']*100:.2f}% of span)"),
        ("cluster_sim/wait", us,
         f"p50={wait['p50']:.1f}s p99={wait['p99']:.1f}s "
         f"mean={wait['mean']:.1f}s makespan={rep['makespan_s']:.0f}s"),
        ("cluster_sim/policies", us,
         f"tenant_p95_mean easy={acc['easy_tenant_p95_wait_mean_s']:.1f}s "
         f"fair_share={acc['fair_share_tenant_p95_wait_mean_s']:.1f}s "
         f"gang_wait easy={acc['easy_gang_p95_wait_s']:.1f}s "
         f"preempt={acc['priority_preempt_gang_p95_wait_s']:.1f}s "
         f"evictions={acc['priority_preempt_evictions']} "
         f"{'OK' if policy_ok else 'FAIL'}"),
        ("cluster_sim/wall", rep["sim_wall_s"] * 1e6,
         f"sim_wall={rep['sim_wall_s']*1e3:.1f}ms "
         f"events_per_s={rep['sim_events_per_s']:.0f}"),
    ]
