"""Cluster-sim smoke benchmark: the paper's Figs 10-12 at cluster level.

Runs a fixed-seed trace (mixed train/prefill/decode jobs, one injected
failure wave) through ``repro.cluster`` and reports pool utilization,
accelerator under-utilization (AUU), per-link-class traffic, and
recomposition overhead — the perf-trajectory artifact for the control
plane.  ``report()`` returns the JSON dict that ``run.py --bench
cluster_sim`` writes to ``results/cluster_sim.json``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.cluster import TraceConfig
from repro.cluster.simulator import ClusterSimulator

BENCH_CFG = TraceConfig(n_jobs=24, arrival_rate_hz=0.2, seed=7,
                        failures=((120.0, 12),), repair_after_s=180.0)


def report() -> Dict[str, object]:
    sim = ClusterSimulator(BENCH_CFG)
    rep = sim.run()
    rep["bench"] = "cluster_sim"
    # wall-time telemetry lives here, not in the (deterministic) sim report
    rep["sim_wall_s"] = sim.wall_s
    rep["sim_events_per_s"] = sim.events_per_s
    return rep


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    rep = report()
    us = (time.perf_counter() - t0) * 1e6
    jobs = rep["jobs"]
    rec = rep["recomposition"]
    wait = rep["job_wait_s"]
    lt = rep["link_traffic_gb"]
    ok = (jobs["completed"] + jobs["rejected"] == jobs["submitted"]
          and jobs["stranded"] == 0 and rep["lease_conflicts"] == 0)
    return [
        ("cluster_sim/jobs", us,
         f"submitted={jobs['submitted']} completed={jobs['completed']} "
         f"rejected={jobs['rejected']} preempted={jobs['preempted']} "
         f"stranded={jobs['stranded']} "
         f"conflicts={rep['lease_conflicts']} "
         f"{'OK' if ok else 'FAIL'}"),
        ("cluster_sim/utilization", us,
         f"pool_util={rep['pool_utilization']*100:.1f}% "
         f"AUU={rep['auu']*100:.1f}% "
         f"(AU={rep['accelerator_utilization']*100:.1f}%)"),
        ("cluster_sim/traffic", us,
         "per-link GB: " + " ".join(
             f"{k}={v:.0f}" for k, v in lt.items())),
        ("cluster_sim/recompose", us,
         f"count={rec['count']} overhead={rec['overhead_s']:.2f}s "
         f"({rec['overhead_frac']*100:.2f}% of span)"),
        ("cluster_sim/wait", us,
         f"p50={wait['p50']:.1f}s p99={wait['p99']:.1f}s "
         f"mean={wait['mean']:.1f}s makespan={rep['makespan_s']:.0f}s"),
        ("cluster_sim/wall", rep["sim_wall_s"] * 1e6,
         f"sim_wall={rep['sim_wall_s']*1e3:.1f}ms "
         f"events_per_s={rep['sim_events_per_s']:.0f}"),
    ]
