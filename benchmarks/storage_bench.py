"""Storage-composability benchmark: tranche contention, local vs switch.

The paper's §V-3 measures one workload against one NVMe placement at a
time (localNVMe vs falconNVMe).  This benchmark sweeps the question the
composable pitch actually raises: what happens when the *switch* lets N
tenants attach the **same** tranche, versus each tenant composing its own
host-local one?

Two layers:

  * **sweep** — analytic: 1..4 co-located tenants on one switch-attached
    tranche vs the same tenants on separate local tranches, priced with
    the MLPerf-Storage-style trace generator (shuffled-epoch reads +
    checkpoint bursts) over the contended ``StorageModel``.
  * **cluster** — the trace-driven simulator end-to-end: identical
    input-heavy training jobs admitted through the scheduler (which now
    requires a storage lease), once against a single shared
    switch-attached tranche and once against per-tenant local tranches;
    reports per-tranche ``StorageStats`` (occupancy, bytes, input-stall
    seconds) and the makespan gap.

``report()`` is the JSON artifact ``run.py --bench storage_bench`` writes
to ``results/storage_bench.json``; schema asserted by
``tests/test_artifacts.py``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.cluster.simulator import ClusterSimulator, JobTemplate, TraceConfig
from repro.core.topology import DEFAULT_LINKS, LinkClass
from repro.data.pipeline import (IOTraceGenerator, IOWorkload, StorageModel,
                                 workload_stall)
from repro.data.storage import StorageTranche

MAX_TENANTS = 4

# An input-heavy tenant (multimodal-frame-class records: 1 MB +- 300 KB,
# 512-sample global batch) with periodic 2 GB checkpoint bursts — the
# workload class where the paper's NVMe placement actually matters.
HEAVY_IO = IOWorkload("heavy-input", 1e6, 0.3e6, batch_size=512,
                      samples_per_epoch=1 << 16,
                      checkpoint_bytes=2e9, checkpoint_every=20)
STEP_S = 0.25                       # representative compute step time


# Perf-trajectory spec for results/BENCH_storage_bench.json (see
# docs/tracking.md).  The whole bench is analytic + fixed-seed, so every
# metric is machine-independent and gateable.
TRAJECTORY = {
    "shared_stall_s": {"direction": "down"},
    "separate_stall_s": {"direction": "down"},
    "contention_slowdown_t2": {"direction": "down"},
    "contention_slowdown_t4": {"direction": "down"},
    "makespan_gap_s": {"direction": "down"},
}


def trajectory_row(rep: Dict[str, object]) -> Dict[str, float]:
    """Flatten one report() into the gated summary-row metrics."""
    acc = rep["cluster"]["acceptance"]
    return {
        "shared_stall_s": acc["shared_stall_s"],
        "separate_stall_s": acc["separate_stall_s"],
        "contention_slowdown_t2":
            rep["sweep"]["tenants_2"]["contention_slowdown"],
        "contention_slowdown_t4":
            rep["sweep"]["tenants_4"]["contention_slowdown"],
        "makespan_gap_s": acc["makespan_gap_s"],
    }


def _tranche(attach: LinkClass, i: int = 0) -> StorageTranche:
    name = f"{'local' if attach == LinkClass.LOCAL else 'falcon'}-nvme-{i}"
    return StorageTranche(name, attach=attach)


def sweep() -> Dict[str, Dict[str, object]]:
    """Per-tenant stall/throughput, shared switch vs separate local."""
    gen = IOTraceGenerator(HEAVY_IO, seed=0)
    mean_read = float(gen.read_trace(64).mean())
    out: Dict[str, Dict[str, object]] = {}
    for n in range(1, MAX_TENANTS + 1):
        shared = StorageModel(_tranche(LinkClass.SWITCH).spec(),
                              dict(DEFAULT_LINKS), n_lessees=n)
        local = StorageModel(_tranche(LinkClass.LOCAL).spec(),
                             dict(DEFAULT_LINKS), n_lessees=1)
        stall_sh = workload_stall(HEAVY_IO, shared, STEP_S)
        stall_lo = workload_stall(HEAVY_IO, local, STEP_S)
        out[f"tenants_{n}"] = {
            "n_tenants": n,
            "mean_step_read_mb": mean_read / 1e6,
            "shared_switch": {
                "per_tenant_read_bw_gbps": shared.tier.effective_read_bw(
                    shared.links) / n / 1e9,
                "input_stall_s": stall_sh,
                "step_s": STEP_S + stall_sh,
            },
            "local_per_tenant": {
                "per_tenant_read_bw_gbps": local.tier.effective_read_bw(
                    local.links) / 1e9,
                "input_stall_s": stall_lo,
                "step_s": STEP_S + stall_lo,
            },
            "contention_slowdown": (STEP_S + stall_sh) / (STEP_S + stall_lo),
        }
    return out


def _trace(tranches: Tuple[StorageTranche, ...], n_jobs: int) -> TraceConfig:
    tmpl = (JobTemplate("qwen2-0.5b", "train_4k", 16, 30, io=HEAVY_IO),)
    return TraceConfig(n_jobs=n_jobs, arrival_rate_hz=5.0, seed=1,
                       n_local=64, n_switch=0, pods=1, templates=tmpl,
                       failures=(), storage_tranches=tranches)


def cluster(n_jobs: int = 3) -> Dict[str, object]:
    shared = ClusterSimulator(
        _trace((_tranche(LinkClass.SWITCH),), n_jobs)).run()
    separate = ClusterSimulator(
        _trace(tuple(_tranche(LinkClass.LOCAL, i) for i in range(n_jobs)),
               n_jobs)).run()

    def view(rep):
        return {
            "jobs": rep["jobs"],
            "makespan_s": rep["makespan_s"],
            "auu": rep["auu"],
            "storage": rep["storage"],
            "input_stall_s_total": sum(
                s["input_stall_s"] for s in rep["storage"].values()),
        }

    sh, se = view(shared), view(separate)
    return {
        "n_tenants": n_jobs,
        "shared_switch_tranche": sh,
        "separate_local_tranches": se,
        "acceptance": {
            # >= 2 tenants on one switch tranche must stall harder than
            # the same tenants on their own local tranches
            "shared_stall_s": sh["input_stall_s_total"],
            "separate_stall_s": se["input_stall_s_total"],
            "contention_visible": (sh["input_stall_s_total"]
                                   > se["input_stall_s_total"]),
            "makespan_gap_s": sh["makespan_s"] - se["makespan_s"],
        },
    }


def report() -> Dict[str, object]:
    return {
        "bench": "storage_bench",
        "config": {
            "io_workload": {
                "name": HEAVY_IO.name,
                "record_bytes": HEAVY_IO.record_bytes,
                "record_stdev": HEAVY_IO.record_stdev,
                "batch_size": HEAVY_IO.batch_size,
                "checkpoint_bytes": HEAVY_IO.checkpoint_bytes,
                "checkpoint_every": HEAVY_IO.checkpoint_every,
            },
            "step_s": STEP_S,
            "max_tenants": MAX_TENANTS,
        },
        "sweep": sweep(),
        "cluster": cluster(),
    }


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    rep = report()
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    for key, row in rep["sweep"].items():
        rows.append((
            f"storage_bench/{key}", us,
            f"shared_stall={row['shared_switch']['input_stall_s']*1e3:.0f}ms "
            f"local_stall={row['local_per_tenant']['input_stall_s']*1e3:.0f}ms "
            f"slowdown={row['contention_slowdown']:.2f}x"))
    acc = rep["cluster"]["acceptance"]
    rows.append((
        "storage_bench/cluster", us,
        f"shared_stall={acc['shared_stall_s']:.1f}s "
        f"separate_stall={acc['separate_stall_s']:.1f}s "
        f"makespan_gap={acc['makespan_gap_s']:.1f}s "
        f"{'OK' if acc['contention_visible'] else 'FAIL'}"))
    return rows
