"""Fig 15 (storage): localNVMe vs falconNVMe input-path impact.

The paper observes: NVMe acceleration helps input-heavy models (YOLO,
BERT fine-tuning reads big records); the falcon switch adds only a small
penalty on the storage path because reads overlap compute (prefetching).
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.paper_model import PAPER_WORKLOADS, step_time
from repro.core.topology import DEFAULT_LINKS, LOCAL_NVME, SWITCH_NVME
from repro.data import StorageModel, input_stall

# per-sample input bytes (ImageNet JPEG ~110KB; COCO 640px ~300KB; SQuAD
# tokenized record ~6KB).  Deviation note: the paper reports NVMe helping
# BERT too; tokenized-SQuAD reads are tiny, so our model shows ~no BERT
# effect — their gain likely includes checkpoint I/O (Fig 9 dips), which
# we model separately in the checkpoint layer.
SAMPLE_BYTES = {"mobilenetv2": 110e3, "resnet50": 110e3, "yolov5l": 300e3,
                "bert-base": 6e3, "bert-large": 6e3}
HDD_BW = 0.2e9    # the no-NVMe baseline the paper accelerates from


def run() -> List[Tuple[str, float, str]]:
    rows = []
    local = StorageModel(LOCAL_NVME)
    falcon = StorageModel(SWITCH_NVME)
    # real dataloaders overlap only partially (CPU augmentation sits on
    # the critical path); reads hide under half the step
    def stall(read_s, step_s):
        return max(0.0, read_s - 0.5 * step_s)

    for w in PAPER_WORKLOADS:
        t0 = time.perf_counter()
        comp = step_time(w, "localGPUs")
        nbytes = w.batch_size * SAMPLE_BYTES[w.name]
        stall_hdd = stall(nbytes / HDD_BW, comp)
        stall_local = stall(local.read_time(nbytes), comp)
        stall_falcon = stall(falcon.read_time(nbytes), comp)
        us = (time.perf_counter() - t0) * 1e6
        speedup = (comp + stall_hdd) / (comp + stall_local)
        penalty = ((comp + stall_falcon) - (comp + stall_local)) \
            / (comp + stall_local) * 100
        rows.append((f"fig15/{w.name}", us,
                     f"nvme_speedup_vs_hdd={speedup:.2f}x "
                     f"falcon_nvme_penalty={penalty:+.1f}% "
                     f"(paper: penalty small, speedup largest for "
                     f"input-heavy)"))
    return rows
