"""Fig 15 (storage): localNVMe vs falconNVMe input-path impact.

The paper observes: NVMe acceleration helps input-heavy models (YOLO,
BERT fine-tuning reads big records); the falcon switch adds only a small
penalty on the storage path because reads overlap compute (prefetching).

Per-step read bytes come from the MLPerf-Storage-style trace generator
(``repro.data.pipeline.IOTraceGenerator``): per-sample record-size
distributions + per-epoch shuffled reads, instead of the former flat
bytes-per-sample constant.  A third column prices the same read against
a falcon tranche shared by a co-tenant (the composability cost the
paper's single-tenant chassis could not measure).
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.paper_model import PAPER_WORKLOADS, step_time
from repro.core.topology import DEFAULT_LINKS, LOCAL_NVME, SWITCH_NVME
from repro.data import IO_WORKLOADS, IOTraceGenerator, StorageModel

# Deviation note: the paper reports NVMe helping BERT too; tokenized-SQuAD
# reads are tiny, so our model shows ~no BERT effect — their gain likely
# includes checkpoint I/O (Fig 9 dips), which the IOWorkload's
# checkpoint-burst term models separately.
HDD_BW = 0.2e9    # the no-NVMe baseline the paper accelerates from
TRACE_STEPS = 64  # steps averaged from the shuffled-read trace


def run() -> List[Tuple[str, float, str]]:
    rows = []
    local = StorageModel(LOCAL_NVME)
    falcon = StorageModel(SWITCH_NVME)
    shared2 = StorageModel(SWITCH_NVME, dict(DEFAULT_LINKS), n_lessees=2)
    # real dataloaders overlap only partially (CPU augmentation sits on
    # the critical path); reads hide under half the step
    def stall(read_s, step_s):
        return max(0.0, read_s - 0.5 * step_s)

    for w in PAPER_WORKLOADS:
        t0 = time.perf_counter()
        comp = step_time(w, "localGPUs")
        io = IO_WORKLOADS[w.name]
        gen = IOTraceGenerator(io, seed=0)
        nbytes = float(gen.read_trace(TRACE_STEPS).mean()) \
            * (w.batch_size / io.batch_size)
        stall_hdd = stall(nbytes / HDD_BW, comp)
        stall_local = stall(local.read_time(nbytes), comp)
        stall_falcon = stall(falcon.read_time(nbytes), comp)
        stall_shared = stall(shared2.read_time(nbytes), comp)
        us = (time.perf_counter() - t0) * 1e6
        speedup = (comp + stall_hdd) / (comp + stall_local)
        penalty = ((comp + stall_falcon) - (comp + stall_local)) \
            / (comp + stall_local) * 100
        shared_pen = ((comp + stall_shared) - (comp + stall_local)) \
            / (comp + stall_local) * 100
        rows.append((f"fig15/{w.name}", us,
                     f"nvme_speedup_vs_hdd={speedup:.2f}x "
                     f"falcon_nvme_penalty={penalty:+.1f}% "
                     f"falcon_shared2_penalty={shared_pen:+.1f}% "
                     f"(paper: penalty small, speedup largest for "
                     f"input-heavy)"))
    return rows
