"""Shared analytic model of the paper's 8-GPU DDP experiment.

The paper trains 5 benchmarks data-parallel on 8 V100s and varies the
fabric (Table III).  To reproduce its *relative* results (Fig 11/12/15/16)
without GPUs, we price one training step as

    step = overhead + compute + max(0, comm(fabric) - overlap*compute)

Calibration (all from public, era-correct sources; documented in
EXPERIMENTS.md):
  * compute = batch / (8 x published V100 fp16 DDP throughput) — NGC-era
    per-GPU figures; this captures the per-model efficiency differences a
    flat-MFU model misses (depthwise convs run at ~3% MFU, BERT at ~35%).
  * gradients are exchanged in FP32 (torch.cuda.amp keeps fp32 master
    grads; NCCL allreduce payload = 4 B/param even under mixed precision).
  * fabric bandwidth under an 8-way concurrent ring is a SHARED ceiling:
    NVLink gives every pair dedicated links (Table IV L-L 72.37 GB/s),
    but the Falcon switch funnels all 8 GPUs through the chassis -> the
    effective per-GPU bandwidth is aggregate/8.  We take the aggregate
    from the paper's own Fig-12 peak measurement (76.43 GB/s).  Hybrid
    crosses the host root complex (F-L 19.64 GB/s per direction) shared
    by the 4 switch-attached GPUs.
  * overlap: PyTorch DDP hides buckets under backward; 0.4 of compute.
  * overhead: fixed 35 ms/step (input pipeline + launch), visible in the
    paper's small-model step times (Fig 12: MobileNet 4 GB/s at 0.19 GB
    exchanged/step -> ~47 ms steps despite ~6 ms of compute).

Absolute seconds are NOT the deliverable (hardware-specific); orderings,
percent-changes and traffic ratios are — those the paper publishes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.paper_bench import PAPER_WORKLOADS, PaperWorkload

N_GPUS = 8

# published per-V100 fp16 DDP training throughput (samples/s/GPU)
THROUGHPUT = {"mobilenetv2": 1400.0, "resnet50": 410.0, "yolov5l": 85.0,
              "bert-base": 105.0, "bert-large": 30.0}

GRAD_BYTES = 4            # torch amp: fp32 master grads on the wire
OVERLAP = 0.4             # DDP bucket overlap with backward
STEP_OVERHEAD = 0.035     # input pipeline + launch, seconds

# effective per-GPU bandwidth during an 8-way concurrent ring (bytes/s)
FALCON_AGGREGATE = 76.43e9            # paper Fig-12 measured switch peak
EFF_BW = {
    "localGPUs": 72.37e9,             # NVLink: dedicated per-pair links
    "falconGPUs": FALCON_AGGREGATE / N_GPUS,
    "hybridGPUs": 19.64e9 / 2.0,      # F-L host hop shared by 4 GPUs
}


def compute_time(w: PaperWorkload) -> float:
    return w.batch_size / (N_GPUS * THROUGHPUT[w.name])


def allreduce_wire_bytes(params: float,
                         dtype_bytes: int = GRAD_BYTES) -> float:
    """Per-GPU ring-allreduce wire bytes for one gradient exchange."""
    return 2.0 * (N_GPUS - 1) / N_GPUS * params * dtype_bytes


def comm_time(w: PaperWorkload, config: str,
              dtype_bytes: int = GRAD_BYTES) -> float:
    return allreduce_wire_bytes(w.params_paper, dtype_bytes) \
        / EFF_BW[config]


def step_time(w: PaperWorkload, config: str, *,
              dtype_bytes: int = GRAD_BYTES,
              overlap: float = OVERLAP) -> float:
    c = compute_time(w)
    m = comm_time(w, config, dtype_bytes)
    return STEP_OVERHEAD + c + max(0.0, m - overlap * c)


def overhead_vs_local(w: PaperWorkload, config: str) -> float:
    """Fig-11 quantity: % change of training time vs localGPUs."""
    t0 = step_time(w, "localGPUs")
    return (step_time(w, config) - t0) / t0 * 100.0


def fabric_traffic_gbps(w: PaperWorkload, config: str = "falconGPUs"
                        ) -> float:
    """Fig-12 quantity: sustained GB/s through the switch (ingress+egress
    over all ports) = exchanged bytes per step / step time."""
    per_gpu = allreduce_wire_bytes(w.params_paper)
    total = per_gpu * N_GPUS * 2.0        # ingress + egress counted
    return total / step_time(w, config) / 1e9
