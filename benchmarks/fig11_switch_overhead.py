"""Fig 11/15: % change of training time vs localGPUs across fabrics.

Paper claims reproduced here:
  * vision models: < 7% overhead on falcon-attached GPUs
  * overhead grows with parameter count
  * BERT-large: ~2x training time on falconGPUs
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.paper_model import PAPER_WORKLOADS, overhead_vs_local, \
    step_time


def run() -> List[Tuple[str, float, str]]:
    rows = []
    ordered = sorted(PAPER_WORKLOADS, key=lambda w: w.params_paper)
    falcon = {}
    for w in ordered:
        t0 = time.perf_counter()
        hy = overhead_vs_local(w, "hybridGPUs")
        fa = overhead_vs_local(w, "falconGPUs")
        falcon[w.name] = fa
        us = (time.perf_counter() - t0) * 1e6
        checks = []
        if w.domain == "vision":
            checks.append("vision<7%:" + ("OK" if fa < 7 else "FAIL"))
        if w.name == "bert-large":
            checks.append("~2x:" + ("OK" if 60 <= fa <= 160 else "FAIL"))
        rows.append((f"fig11/{w.name}", us,
                     f"hybrid={hy:+.1f}% falcon={fa:+.1f}% "
                     f"params={w.params_paper/1e6:.0f}M "
                     + " ".join(checks)))
    # the paper's correlation claim: overhead(vision) << overhead(NLP),
    # growing with parameter count across the NLP pair
    vis_max = max(v for k, v in falcon.items()
                  if k in ("mobilenetv2", "resnet50", "yolov5l"))
    ok = vis_max <= falcon["bert-base"] <= falcon["bert-large"]
    rows.append(("fig11/size-correlation", 0.0,
                 f"max(vision)={vis_max:.1f}% <= bert-base="
                 f"{falcon['bert-base']:.1f}% <= bert-large="
                 f"{falcon['bert-large']:.1f}%: "
                 + ("OK" if ok else "FAIL")))
    return rows
