"""Roofline table: 40-cell (arch x shape) terms from the dry-run artifacts.

Reads results/dryrun/*.json (produced by ``repro.launch.dryrun``) and
prints per-cell compute/memory/collective seconds, dominant term, useful
ratio and roofline fraction, for both meshes.
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import List, Tuple

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def run() -> List[Tuple[str, float, str]]:
    rows = []
    files = sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json")))
    if not files:
        return [("roofline/missing", 0.0,
                 f"no dry-run artifacts under {RESULTS_DIR}; run "
                 "PYTHONPATH=src python -m repro.launch.dryrun first")]
    worst = (None, 1e9)
    most_coll = (None, -1.0)
    for path in files:
        t0 = time.perf_counter()
        with open(path) as f:
            js = json.load(f)
        rl = js.get("roofline", {})
        us = (time.perf_counter() - t0) * 1e6
        tag = os.path.basename(path)[:-5]
        frac = rl.get("roofline_fraction", 0.0)
        coll = rl.get("collective_s", 0.0)
        step = rl.get("step_time_s", 1e-30)
        if frac < worst[1] and "single" in tag:
            worst = (tag, frac)
        if coll / step > most_coll[1] and "single" in tag:
            most_coll = (tag, coll / step)
        rows.append((f"roofline/{tag}", us,
                     f"compute={rl.get('compute_s', 0)*1e3:.2f}ms "
                     f"memory={rl.get('memory_s', 0)*1e3:.2f}ms "
                     f"collective={coll*1e3:.2f}ms "
                     f"dominant={rl.get('dominant','?')} "
                     f"frac={frac:.3f} "
                     f"useful={rl.get('useful_ratio', 0):.3f}"))
    rows.append(("roofline/summary", 0.0,
                 f"cells={len(files)} worst_fraction={worst[0]}({worst[1]:.3f}) "
                 f"most_collective_bound={most_coll[0]}"
                 f"({most_coll[1]*100:.0f}% of step)"))
    return rows
