"""The paper's experiment as a library call: one workload, many fabrics.

Builds the five Table-III compositions, prices a BERT-large-class training
step on each, and prints the Fig-11 percent-overhead table — then shows
the elastic path: fail devices, recompose, and carry on.

    PYTHONPATH=src python examples/compose_experiment.py
"""
from repro.core import compose, costmodel
from repro.core.recommend import recommend, recommend_from_measurements
from repro.core.topology import LinkClass, make_pool
from benchmarks.paper_model import PAPER_WORKLOADS, overhead_vs_local, \
    step_time


def main():
    print("=== Table III compositions ===")
    for label in compose.PRESET_LABELS:
        sys_ = compose.preset(label)
        links = {a: sys_.fabric.axis_links[a].value
                 for a in sys_.axis_names}
        print(f"{label:12s} mesh={dict(zip(sys_.axis_names, sys_.axis_sizes))} "
              f"links={links} storage={sys_.fabric.storage.name}")

    print("\n=== Fig 11: % training-time change vs localGPUs ===")
    for w in sorted(PAPER_WORKLOADS, key=lambda w: w.params_paper):
        hy = overhead_vs_local(w, "hybridGPUs")
        fa = overhead_vs_local(w, "falconGPUs")
        print(f"{w.name:12s} ({w.params_paper/1e6:6.0f}M params)  "
              f"hybrid {hy:+6.1f}%   falcon {fa:+6.1f}%")

    print("\n=== Elastic recomposition after failures ===")
    pool = make_pool(n_local=300, n_switch=0, pods=1)
    sys_ = compose.compose(pool, "prod", ("data", "model"), (16, 16),
                           {"data": LinkClass.LOCAL,
                            "model": LinkClass.LOCAL})
    print(f"composed {sys_.n_devices} devices")
    pool.mark_failed(list(sys_.device_uids[:10]))
    sys2 = compose.recompose(pool, sys_)
    print(f"10 devices failed -> recomposed from spares: "
          f"{sys2.n_devices} devices, overlap with dead: "
          f"{len(set(sys_.device_uids[:10]) & set(sys2.device_uids))}")
    pool.mark_failed([d.uid for d in pool.devices[:80]])
    sys3 = compose.shrink_to_pool(pool, sys2, "data")
    print(f"80 more failed -> shrunk composition: "
          f"{dict(zip(sys3.axis_names, sys3.axis_sizes))} "
          f"(restore latest checkpoint onto the new mesh and continue)")


def recommend_demo():
    print("\n=== Topology recommendation (the paper's §VI future work) ===")
    for arch, shape in (("mamba2-780m", "train_4k"),
                        ("command-r-35b", "train_4k"),
                        ("command-r-35b", "prefill_32k")):
        cands = recommend(arch, shape, top=3)
        best = recommend_from_measurements(
            ["results/dryrun", "results/optimized"], arch, shape)
        note = f" | measured best: {best.label} ({best.step_s*1e3:.0f}ms)" \
            if best else ""
        print(f"{arch:22s} {shape:12s} analytic: "
              + ", ".join(f"{c.label}={c.step_s*1e3:.0f}ms"
                          for c in cands) + note)


if __name__ == "__main__":
    main()
    recommend_demo()
