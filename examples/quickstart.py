"""Quickstart: train a tiny LM, checkpoint it, and serve greedily.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import PolicyConfig, ShapeConfig
from repro.data import make_batch
from repro.models import lm
from repro.optim import AdamWConfig, ScheduleConfig
from repro.serve import Request, ServeEngine
from repro.train import checkpoint, trainer


def main():
    # 1. pick an architecture from the registry and shrink it for CPU
    cfg = reduced(get_config("llama3.2-3b"))
    policy = PolicyConfig(compute_dtype="float32", remat="none",
                          attn_impl="full", zero_stage=0)
    optcfg = AdamWConfig(lr=1e-3)
    shape = ShapeConfig("demo", seq_len=64, global_batch=8, kind="train")

    # 2. train a few steps on synthetic data
    state = trainer.init_state(jax.random.PRNGKey(0), cfg, policy, optcfg)
    step = jax.jit(trainer.make_train_step(
        cfg, policy, optcfg,
        ScheduleConfig(peak_lr=1e-3, warmup_steps=5, total_steps=30)))
    for i in range(15):
        state, metrics = step(state, make_batch(cfg, shape, step=i))
        if i % 5 == 0:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}")

    # 3. checkpoint (atomic) and restore
    path = checkpoint.save("/tmp/quickstart_ckpt", 15, state)
    print("checkpointed to", path)
    restored, at = checkpoint.restore("/tmp/quickstart_ckpt", state)
    print("restored step", at)

    # 4. serve a couple of greedy continuations from the trained weights
    eng = ServeEngine(cfg, restored.params, policy, n_slots=2, max_seq=96)
    reqs = [Request(i, jax.random.randint(jax.random.PRNGKey(i), (16,),
                                          0, cfg.vocab_size), max_new=8)
            for i in range(2)]
    for r in reqs:
        eng.add_request(r)
    while any(not r.done for r in reqs):
        eng.step()
    for r in reqs:
        print(f"request {r.rid}: generated {r.out}")


if __name__ == "__main__":
    main()
