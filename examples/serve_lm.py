"""Serving example: continuous batching over a reduced model.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
"""
import argparse
import sys

from repro.launch import serve as serve_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    sys.argv = ["serve", "--arch", args.arch,
                "--requests", str(args.requests),
                "--slots", "4", "--prompt-len", "24",
                "--max-new", "12", "--max-seq", "96"]
    return serve_cli.main()


if __name__ == "__main__":
    raise SystemExit(main())
