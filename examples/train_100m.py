"""End-to-end driver: train a ~100M-param model for a few hundred steps.

This is the deliverable-(b) scale run (CPU-sized batch; the same code
drives the production mesh on real hardware via launch/train.py).

    PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse
import sys

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()
    sys.argv = [
        "train",
        "--arch", args.arch,
        "--preset", "100m",
        "--steps", str(args.steps),
        "--batch", "4",
        "--seq", "256",
        "--ckpt", "/tmp/train_100m_ckpt",
        "--ckpt-every", "50",
        "--resume", "auto",
        "--log-every", "10",
    ]
    return train_cli.main()


if __name__ == "__main__":
    raise SystemExit(main())
