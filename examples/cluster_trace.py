"""Cluster control-plane walkthrough: many tenants, one composable pool.

Simulates a 24-job mixed train/serve trace over the 512-device pool
(2 pods x 128 local-fabric + 128 switch-attached chips each), with a
12-device failure wave injected mid-trace and repaired later.  Every job
leases an exclusive slice, placed domain-aware so its tensor-parallel
axis stays on the fast fabric; failures trigger the elastic
recompose-or-shrink path from ``repro.train.elastic``.

    PYTHONPATH=src python examples/cluster_trace.py
"""
from repro.cluster import ClusterSimulator, TraceConfig


def main():
    cfg = TraceConfig(n_jobs=24, arrival_rate_hz=0.2, seed=7,
                      failures=((120.0, 12),), repair_after_s=180.0)
    sim = ClusterSimulator(cfg)
    print(f"=== trace: {cfg.n_jobs} jobs over "
          f"{len(sim.pool.devices)} pooled devices "
          f"(failure wave at t={cfg.failures[0][0]:.0f}s) ===")
    rep = sim.run()

    print("\n=== event log (control-plane actions) ===")
    interesting = ("start", "fail", "recompose", "preempt", "repair",
                   "reject", "conflict")
    for ev in sim.telemetry.events:
        if ev.kind in interesting:
            who = f" {ev.job}" if ev.job else ""
            print(f"t={ev.t:7.1f}s {ev.kind:10s}{who}  {ev.detail}")

    print("\n=== per-job summary ===")
    for job in sorted(sim.scheduler.done, key=lambda j: j.start_t):
        dp, tp = job.system.axis_sizes
        links = ",".join(f"{a}:{c.value}"
                         for a, c in job.system.fabric.axis_links.items())
        rec = f" recomposed x{job.recompositions}" if job.recompositions \
            else ""
        print(f"{job.name:40s} mesh={dp}x{tp} [{links}] "
              f"wait={job.start_t - job.submit_t:5.1f}s "
              f"ran={job.end_t - job.start_t:6.1f}s{rec}")

    print("\n=== cluster report ===")
    jobs = rep["jobs"]
    print(f"jobs: {jobs['completed']}/{jobs['submitted']} completed, "
          f"{jobs['rejected']} rejected, {jobs['preempted']} preempted, "
          f"{jobs['stranded']} stranded")
    print(f"lease conflicts: {rep['lease_conflicts']}")
    print(f"pool utilization: {rep['pool_utilization']*100:.1f}%   "
          f"AUU: {rep['auu']*100:.1f}%")
    print("per-link traffic (GB): " + "  ".join(
        f"{k}={v:,.0f}" for k, v in rep["link_traffic_gb"].items()))
    print(f"recompositions: {rep['recomposition']['count']} "
          f"(overhead {rep['recomposition']['overhead_s']:.2f}s, "
          f"{rep['recomposition']['overhead_frac']*100:.2f}% of span)")
    print(f"job wait: p50={rep['job_wait_s']['p50']:.1f}s "
          f"p99={rep['job_wait_s']['p99']:.1f}s   "
          f"makespan={rep['makespan_s']:.0f}s")

    assert jobs["completed"] == jobs["submitted"], "jobs left incomplete"
    assert rep["lease_conflicts"] == 0, "lease conflict detected"
    assert rep["recomposition"]["count"] >= 1, "failure wave had no effect"
    print("\nall jobs completed; zero lease conflicts.")


if __name__ == "__main__":
    main()
