#!/usr/bin/env python3
"""Docs integrity gate (run in CI; stdlib only).

Checks, over README.md + docs/*.md:

  1. **Dead relative links** — every ``[text](path)`` markdown link
     that is not http(s)/mailto/anchor must resolve to a file or
     directory relative to the file that contains it.
  2. **Stale module references** — every backticked repo path
     (``src/...``, ``docs/...``, ``benchmarks/...``, ``tests/...``,
     ``examples/...``, ``scripts/...``, ``configs/...``, ``results/<x>.json``)
     and every backticked dotted module (``repro.x.y``) must exist.
  3. **Artifact schema drift** — for each ``<!-- schema: NAME -->``
     block in docs/artifacts.md, the fenced JSON object's top-level
     keys must equal the top-level keys of ``results/NAME.json`` (when
     that artifact exists), and every shipped ``results/*.json`` must
     have a schema block.  Perf trajectories (``BENCH_<bench>.json``)
     all share one ``<!-- schema: BENCH -->`` block; the per-run event
     streams under ``results/runs/`` are documented in docs/tracking.md
     and generated at runtime, so references to them are not required
     to resolve on a clean checkout.

Exit status 0 = clean; 1 = problems (all printed).
"""
from __future__ import annotations

import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
SCHEMA_RE = re.compile(
    r"<!--\s*schema:\s*([\w-]+)\s*-->\s*```json\n(.*?)```", re.DOTALL)
# backticked tokens that look like repo paths
PATH_PREFIXES = ("src/", "docs/", "benchmarks/", "tests/", "examples/",
                 "scripts/", "configs/", "results/")
DOTTED_RE = re.compile(r"^repro(\.\w+)+$")


def _md_files():
    out = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        out += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                      if f.endswith(".md"))
    return out


def check_links(path: str, text: str):
    errs = []
    base = os.path.dirname(path)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            errs.append(f"{os.path.relpath(path, ROOT)}: dead link -> "
                        f"{target}")
    return errs


def _path_exists(token: str) -> bool:
    # tolerate trailing slashes and informal "dir/..." suffixes
    token = token.rstrip("/").split(" ")[0]
    if token.endswith("/..."):
        token = token[:-4]
    # the tracking plane's per-run streams are generated at runtime
    # (results/runs/ is gitignored): documented paths need not resolve
    if token.startswith("results/runs"):
        return True
    full = os.path.join(ROOT, token)
    # "benchmarks/cluster_sim"-style module references omit the .py
    return os.path.exists(full) or os.path.exists(full + ".py")


def check_module_refs(path: str, text: str):
    errs = []
    for token in CODE_RE.findall(text):
        token = token.strip()
        if token.startswith(PATH_PREFIXES):
            # strip informal decorations: "src/repro/core (topology, ...)"
            bare = token.split(" (")[0].split("#")[0]
            if any(ch in bare for ch in "*{<>$"):
                continue                      # glob/placeholder, not a path
            if not _path_exists(bare):
                errs.append(f"{os.path.relpath(path, ROOT)}: stale path "
                            f"reference `{token}`")
        elif DOTTED_RE.match(token):
            mod = os.path.join(ROOT, "src", *token.split("."))
            if not (os.path.isdir(mod) or os.path.exists(mod + ".py")):
                errs.append(f"{os.path.relpath(path, ROOT)}: stale module "
                            f"reference `{token}`")
    return errs


def check_artifact_schemas():
    errs = []
    art_md = os.path.join(ROOT, "docs", "artifacts.md")
    if not os.path.exists(art_md):
        return [f"docs/artifacts.md missing ({art_md})"]
    with open(art_md) as f:
        text = f.read()
    documented = {}
    for name, body in SCHEMA_RE.findall(text):
        try:
            documented[name] = set(json.loads(body))
        except json.JSONDecodeError as e:
            errs.append(f"docs/artifacts.md: schema block {name!r} is not "
                        f"valid JSON: {e}")
    results = os.path.join(ROOT, "results")
    shipped = sorted(f for f in os.listdir(results)
                     if f.endswith(".json")) if os.path.isdir(results) else []
    for fname in shipped:
        name = fname[:-len(".json")]
        # every BENCH_<bench>.json trajectory shares one schema block
        if name.startswith("BENCH_"):
            name = "BENCH"
        if name not in documented:
            errs.append(f"results/{fname} has no <!-- schema: {name} --> "
                        "block in docs/artifacts.md")
            continue
        with open(os.path.join(results, fname)) as f:
            actual = set(json.load(f))
        want = documented[name]
        missing = sorted(want - actual)
        extra = sorted(actual - want)
        if missing:
            errs.append(f"results/{fname}: documented keys absent from "
                        f"artifact: {missing}")
        if extra:
            errs.append(f"results/{fname}: artifact keys missing from "
                        f"docs/artifacts.md: {extra}")
    for name in documented:
        # documented-but-unshipped is fine (artifact may be generated in
        # CI only), as long as the block parses — nothing to do
        pass
    return errs


def main() -> int:
    errs = []
    for path in _md_files():
        with open(path) as f:
            text = f.read()
        errs += check_links(path, text)
        errs += check_module_refs(path, text)
    errs += check_artifact_schemas()
    if errs:
        print(f"check_docs: {len(errs)} problem(s)")
        for e in errs:
            print("  -", e)
        return 1
    print("check_docs: OK "
          f"({len(_md_files())} markdown files, schemas in sync)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
