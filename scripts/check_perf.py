#!/usr/bin/env python3
"""Perf-regression CI gate over ``results/BENCH_*.json`` trajectories.

Compares each trajectory's newest row against the median of a trailing
window of prior rows (see :mod:`repro.tracking.gate` for the
direction-aware semantics: throughput-down and p95-wait-up are
regressions; ``info`` metrics are recorded but never gated).

Usage::

    PYTHONPATH=src python scripts/check_perf.py                # gate
    PYTHONPATH=src python scripts/check_perf.py --window 8 --band 0.15
    PYTHONPATH=src python scripts/check_perf.py --update-baseline
    PYTHONPATH=src python scripts/check_perf.py --demo-regression

Exit status: 0 = every gated metric within its noise band (or fresh
baseline); 1 = at least one regression, named in the printed table.

``--update-baseline`` anchors each trajectory's baseline at its newest
row (for intentional perf changes); ``--demo-regression`` proves the
gate works by appending a synthetic 20% regression to a *temporary
copy* of each trajectory and asserting the gate rejects it — the CI
job runs this after the real gate so a silently-broken gate fails the
build.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import statistics
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)               # for benchmarks.<name> specs

from repro.tracking import gate, trajectory  # noqa: E402


def _trajectories(results_dir: str):
    return sorted(glob.glob(os.path.join(results_dir, "BENCH_*.json")))


def _load_checked(path: str):
    """(trajectory, None) or (None, clear one-line reason) — a corrupt
    or rows-less trajectory must fail the gate with a message a human
    can act on, not a traceback."""
    try:
        traj = trajectory.load(path)
    except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
        return None, f"unreadable trajectory ({e})"
    if not isinstance(traj, dict) or not traj.get("bench"):
        return None, "not a trajectory object (missing 'bench' header)"
    if not traj.get("rows"):
        return None, ("header-only trajectory (no summary rows) — run "
                      "`python -m benchmarks.run --bench "
                      f"{traj.get('bench', '<name>')}` to append one")
    return traj, None


def run_gate(results_dir: str, window: int, band: float) -> int:
    paths = _trajectories(results_dir)
    if not paths:
        print(f"check_perf: no BENCH_*.json trajectories in {results_dir!r}"
              " — nothing to gate")
        return 0
    verdicts = []
    broken = []
    for p in paths:
        traj, why = _load_checked(p)
        if traj is None:
            broken.append((p, why))
            continue
        verdicts += gate.check_trajectory(traj, window=window, band=band)
    if verdicts:
        print(gate.format_table(verdicts))
    for p, why in broken:
        print(f"check_perf: FAIL — {os.path.basename(p)}: {why}")
    bad = [v for v in verdicts if v.regressed]
    if bad:
        names = ", ".join(f"{v.bench}/{v.metric}" for v in bad)
        print(f"\ncheck_perf: FAIL — {len(bad)} regressed metric(s): {names}")
    if bad or broken:
        return 1
    gated = sum(1 for v in verdicts if v.direction != "info")
    print(f"\ncheck_perf: OK ({len(paths)} trajectories, "
          f"{gated} gated metrics within the noise band)")
    return 0


def _bench_spec(bench: str):
    """TRAJECTORY metric spec from the bench module (empty on failure —
    the next real append refreshes the spec anyway)."""
    try:
        import importlib
        mod = importlib.import_module(f"benchmarks.{bench}")
        return dict(getattr(mod, "TRAJECTORY", {}))
    except Exception:  # noqa: BLE001
        return {}


def update_baselines(results_dir: str, bench: str = "") -> int:
    if bench:
        paths = [trajectory.path_for(bench, results_dir)]
    else:
        paths = _trajectories(results_dir)
        if not paths:
            print(f"check_perf: no BENCH_*.json trajectories in "
                  f"{results_dir!r} — nothing to update")
            return 0
    rc = 0
    for p in paths:
        if not os.path.exists(p):
            name = bench or os.path.basename(p)[len("BENCH_"):-len(".json")]
            os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
            trajectory._write_atomic(
                p, trajectory.new_trajectory(name, _bench_spec(name)))
            print(f"check_perf: created fresh trajectory {p} "
                  "(header only; baseline anchors on the first row)")
            continue
        traj, why = _load_checked(p)
        if traj is None:
            if why.startswith("header-only"):
                print(f"check_perf: {os.path.basename(p)}: header-only "
                      "(no rows) — baseline unchanged")
                continue
            print(f"check_perf: FAIL — {os.path.basename(p)}: {why}")
            rc = 1
            continue
        traj = gate.update_baseline(traj)
        trajectory._write_atomic(p, traj)
        print(f"check_perf: baseline for {traj['bench']} anchored at "
              f"{traj['baseline_run_id']}")
    return rc


def _degrade(value: float, direction: str, frac: float) -> float:
    # move the metric the *bad* way by `frac` of its magnitude; a zero
    # value cannot be degraded multiplicatively, so nudge it additively
    # past the gate's zero-baseline rule (any worsening movement at all
    # is flagged)
    if value == 0.0:
        return -1.0 if direction == "up" else 1.0
    step = abs(value) * frac
    return value - step if direction == "up" else value + step


def demo_regression(results_dir: str, window: int, band: float,
                    frac: float = 0.20) -> int:
    """Self-test: a synthetic regression must trip the gate.

    Each gated metric is degraded ``frac`` beyond *its own* noise band,
    relative to the trailing-window **median** the gate will compare
    against — not a flat 20% off the newest row.  (A newest row sitting
    above the median, or a metric with a wide custom ``band``, used to
    absorb the flat nudge and falsely fail the self-test.)
    """
    paths = _trajectories(results_dir)
    if not paths:
        print("check_perf: no trajectories — demo skipped")
        return 0
    tmp = tempfile.mkdtemp(prefix="check_perf_demo_")
    try:
        failures = []
        for p in paths:
            dst = os.path.join(tmp, os.path.basename(p))
            shutil.copy(p, dst)
            traj, _why = _load_checked(dst)
            if traj is None:
                continue            # the real gate already reported it
            rows = traj.get("rows", [])
            spec = traj.get("metrics", {})
            gated = {k: m for k, m in spec.items()
                     if m.get("direction") in ("up", "down")}
            if not rows or not gated:
                continue
            last = rows[-1]
            # once the synthetic row is appended it becomes the newest,
            # so the gate's baseline window is the current rows with the
            # current newest *included*
            base_rows = trajectory.window_rows(traj, window,
                                               exclude_last=False)
            bad_metrics = {}
            for k, m in gated.items():
                if k not in last["metrics"]:
                    continue
                history = [float(r["metrics"][k]) for r in base_rows
                           if k in r.get("metrics", {})]
                base = (statistics.median(history) if history
                        else float(last["metrics"][k]))
                bad_metrics[k] = _degrade(
                    base, str(m["direction"]),
                    float(m.get("band", band)) + frac)
            trajectory.append_summary(
                dst, traj["bench"], spec, run_id="synthetic-regression",
                git_sha="0000000", ts=float(last.get("ts", 0.0)) + 1.0,
                metrics={**last["metrics"], **bad_metrics})
            verdicts = gate.check_trajectory(trajectory.load(dst),
                                             window=window, band=band)
            tripped = sorted(v.metric for v in verdicts if v.regressed)
            want = sorted(bad_metrics)
            if tripped != want:
                failures.append((traj["bench"], want, tripped))
            else:
                print(f"check_perf: demo OK — {traj['bench']}: synthetic "
                      f"band+{frac:.0%} regression tripped "
                      f"{len(tripped)} metric(s): {', '.join(tripped)}")
        if failures:
            for bench, want, got in failures:
                print(f"check_perf: demo FAIL — {bench}: expected "
                      f"{want} to regress, gate flagged {got}")
            return 1
        return 0
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--results-dir", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "results"))
    ap.add_argument("--window", type=int, default=gate.DEFAULT_WINDOW,
                    help="trailing-window size for the baseline median")
    ap.add_argument("--band", type=float, default=gate.DEFAULT_BAND,
                    help="default noise band (fraction, e.g. 0.10)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="anchor each baseline at the newest row")
    ap.add_argument("--bench", default="",
                    help="with --update-baseline: target one bench; a "
                         "missing trajectory file is created fresh "
                         "instead of crashing")
    ap.add_argument("--demo-regression", action="store_true",
                    help="self-test: synthetic 20%% regression must trip "
                         "the gate (on temp copies; trajectories untouched)")
    args = ap.parse_args()
    if args.update_baseline:
        return update_baselines(args.results_dir, args.bench)
    if args.demo_regression:
        return demo_regression(args.results_dir, args.window, args.band)
    return run_gate(args.results_dir, args.window, args.band)


if __name__ == "__main__":
    raise SystemExit(main())
