"""Render EXPERIMENTS.md §Roofline tables from results/dryrun/*.json."""
import glob
import json
import os
import sys

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main(results="results/dryrun", mesh="single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(results, f"*__{mesh}.json"))):
        js = json.load(open(path))
        rl = js["roofline"]
        rows.append((js["arch"], js["shape"], rl))
    rows.sort(key=lambda r: (r[0], ORDER.index(r[1])))
    print("| arch | shape | compute | memory (hlo) | collective | dominant"
          " | MODEL/HLO | fraction |")
    print("|---|---|---|---|---|---|---|---|")
    for arch, shape, rl in rows:
        print(f"| {arch} | {shape} "
              f"| {rl['compute_s']*1e3:8.1f}ms "
              f"| {rl['memory_s']*1e3:7.1f}ms ({rl['memory_hlo_s']*1e3:.0f}) "
              f"| {rl['collective_s']*1e3:9.1f}ms "
              f"| {rl['dominant']} "
              f"| {rl['useful_ratio']:.2f} "
              f"| {rl['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
